//! Synthetic corpus: a deterministic token stream with learnable structure.
//!
//! A pure-noise corpus gives a flat loss curve (nothing to learn); instead we
//! generate a Markov-chain "language" with a skewed unigram distribution and
//! strong bigram structure, so the mini model's loss visibly drops from
//! ~ln(V) toward the chain's conditional entropy — the e2e signal recorded
//! in EXPERIMENTS.md.

use crate::util::Rng64;

/// Deterministic synthetic corpus generator.
pub struct SyntheticCorpus {
    vocab: u32,
    rng: Rng64,
    /// Per-state successor table: `succ[state]` = the states this token can
    /// transition to (small out-degree = strong structure).
    succ: Vec<Vec<u32>>,
    state: u32,
}

impl SyntheticCorpus {
    /// `branch` successors per token (2–8 gives a clearly learnable chain).
    pub fn new(vocab: u32, branch: usize, seed: u64) -> Self {
        assert!(vocab >= 2 && branch >= 1);
        let mut rng = Rng64::new(seed);
        let succ = (0..vocab)
            .map(|_| (0..branch).map(|_| rng.below(vocab as u64) as u32).collect())
            .collect();
        Self { vocab, rng, succ, state: 0 }
    }

    /// Next token of the stream.
    pub fn next_token(&mut self) -> u32 {
        let choices = &self.succ[self.state as usize];
        let t = choices[self.rng.below(choices.len() as u64) as usize];
        self.state = t;
        t
    }

    /// One `(tokens, labels)` pair of `n` positions: labels are next-token.
    pub fn sample(&mut self, n: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(n + 1);
        for _ in 0..=n {
            toks.push(self.next_token() as i32);
        }
        let tokens = toks[..n].to_vec();
        let labels = toks[1..].to_vec();
        (tokens, labels)
    }

    /// A full step's worth of data: `data[replica][microbatch]`.
    pub fn step_batch(
        &mut self,
        dp: u64,
        microbatches: u64,
        tokens_per_mb: usize,
    ) -> Vec<Vec<(Vec<i32>, Vec<i32>)>> {
        (0..dp)
            .map(|_| (0..microbatches).map(|_| self.sample(tokens_per_mb)).collect())
            .collect()
    }

    pub fn vocab(&self) -> u32 {
        self.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SyntheticCorpus::new(64, 4, 7);
        let mut b = SyntheticCorpus::new(64, 4, 7);
        assert_eq!(a.sample(32), b.sample(32));
    }

    #[test]
    fn labels_are_shifted_tokens() {
        let mut c = SyntheticCorpus::new(64, 4, 1);
        let (t, l) = c.sample(16);
        assert_eq!(t.len(), 16);
        assert_eq!(l.len(), 16);
        assert_eq!(&t[1..], &l[..15]);
    }

    #[test]
    fn tokens_in_range() {
        let mut c = SyntheticCorpus::new(100, 3, 2);
        let (t, l) = c.sample(1000);
        assert!(t.iter().chain(l.iter()).all(|&x| (0..100).contains(&x)));
    }

    #[test]
    fn bigram_structure_exists() {
        // Each state has ≤ branch distinct successors.
        let mut c = SyntheticCorpus::new(32, 2, 3);
        let (t, _) = c.sample(5000);
        let mut succs: std::collections::HashMap<i32, std::collections::HashSet<i32>> =
            Default::default();
        for w in t.windows(2) {
            succs.entry(w[0]).or_default().insert(w[1]);
        }
        assert!(succs.values().all(|s| s.len() <= 2));
    }

    #[test]
    fn step_batch_shape() {
        let mut c = SyntheticCorpus::new(64, 4, 9);
        let d = c.step_batch(2, 3, 8);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].len(), 3);
        assert_eq!(d[0][0].0.len(), 8);
    }
}
