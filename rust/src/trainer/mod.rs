//! End-to-end trainer: synthetic corpus generation, the training loop over the
//! [`crate::coordinator::PipelineCoordinator`], loss logging and the
//! measured-vs-analytical memory validation (experiment E3).

pub mod data;
pub mod validate;

pub use data::SyntheticCorpus;
pub use validate::MemoryValidation;

use crate::config::{LiveSchedule, TrainingConfig};
use crate::coordinator::PipelineCoordinator;
use crate::runtime::{ArtifactManifest, Runtime};
use crate::schedule::{Schedule, ScheduleSpec};
use std::sync::Arc;

/// Result of a completed training run.
pub struct TrainingRun {
    /// (step, loss) series.
    pub losses: Vec<(u64, f32)>,
    /// Final memory validation (E3).
    pub validation: MemoryValidation,
    /// Mean wall time per step (ms).
    pub mean_step_ms: f64,
}

/// Run the full mini training loop and print progress. Returns the loss
/// series and the E3 validation.
pub fn run_training(
    manifest: ArtifactManifest,
    cfg: TrainingConfig,
) -> anyhow::Result<TrainingRun> {
    let runtime = Arc::new(Runtime::load(manifest)?);
    println!(
        "loaded {} executables on {} (pp={}, b={}, s={})",
        runtime.manifest.executables.len(),
        runtime.platform(),
        cfg.pp,
        cfg.micro_batch,
        cfg.seq_len
    );
    let vocab = runtime.manifest.vocab_size as u32;
    let manifest = runtime.manifest.clone();
    let mut coord = PipelineCoordinator::new(runtime, cfg.clone())?;
    println!("model: {} params across {} stages", coord.total_params(), cfg.pp);

    let mut corpus = SyntheticCorpus::new(vocab, 4, cfg.seed);
    let tokens_per_mb = (cfg.micro_batch * cfg.seq_len) as usize;
    let mut losses = Vec::with_capacity(cfg.steps as usize);
    let mut total_ms = 0.0;
    for step in 1..=cfg.steps {
        let batch = corpus.step_batch(cfg.dp, cfg.num_microbatches, tokens_per_mb);
        let stats = coord.step(&batch)?;
        total_ms += stats.wall_ms;
        losses.push((step, stats.loss));
        if step == 1 || step % cfg.log_every == 0 || step == cfg.steps {
            println!(
                "step {:>5}  loss {:.4}  ({:.0} ms)",
                step, stats.loss, stats.wall_ms
            );
        }
    }

    // E3 validation: measured peaks vs manifest-exact predictions.
    let spec = match cfg.schedule {
        LiveSchedule::GPipe => ScheduleSpec::GPipe,
        LiveSchedule::OneFOneB => ScheduleSpec::OneFOneB,
    };
    let sched = Schedule::build(spec, cfg.pp, cfg.num_microbatches)?;
    let inflight: Vec<u64> = (0..cfg.pp).map(|s| sched.analytic_inflight(s)).collect();
    let opt_shard = if cfg.zero_os { cfg.dp } else { 1 };
    let validation = MemoryValidation::build(
        &manifest,
        &coord.memory_snapshots(),
        &inflight,
        opt_shard,
    )?;
    println!("{}", validation.render());
    println!("max relative error: {:.2}%", 100.0 * validation.max_error());

    Ok(TrainingRun {
        losses,
        validation,
        mean_step_ms: total_ms / cfg.steps.max(1) as f64,
    })
}
