//! Experiment E3: compare the live runtime's *measured* tagged memory
//! against the paper's analytical model evaluated on the mini config.
//!
//! The analytical side uses the same formulas that reproduce Tables 6/8/10;
//! the measured side is the peak tagged bytes of the coordinator's virtual
//! devices. Agreement validates the *structure* of the paper's model (the
//! mini run is FP32/CPU, so absolute bytes differ from the paper's BF16/H800
//! setting by the dtype factor — which the model parameterizes).

use crate::runtime::memory::{MemTag, MemorySnapshot};
use crate::runtime::ArtifactManifest;

/// One compared quantity.
#[derive(Debug, Clone)]
pub struct ValidationRow {
    pub name: String,
    pub stage: u64,
    pub predicted_bytes: u64,
    pub measured_bytes: u64,
}

impl ValidationRow {
    /// measured / predicted.
    pub fn ratio(&self) -> f64 {
        if self.predicted_bytes == 0 {
            return if self.measured_bytes == 0 { 1.0 } else { f64::INFINITY };
        }
        self.measured_bytes as f64 / self.predicted_bytes as f64
    }

    pub fn within(&self, tol: f64) -> bool {
        let r = self.ratio();
        r.is_finite() && (1.0 - tol..=1.0 + tol).contains(&r)
    }
}

/// The full measured-vs-analytical comparison.
#[derive(Debug, Clone)]
pub struct MemoryValidation {
    pub rows: Vec<ValidationRow>,
}

impl MemoryValidation {
    /// Build predictions from the manifest (exact buffer arithmetic) and
    /// compare with the coordinator's measured snapshots.
    ///
    /// * params: Σ param-buffer bytes (manifest) — measured `Params`;
    /// * gradients: params × 4 B fp32 — measured `Gradients`;
    /// * optimizer m+v: 2 × params bytes — measured `OptimizerM+V`
    ///   (divided by ownership share under ZeRO-os, handled by the caller
    ///   passing the effective `opt_shard` divisor);
    /// * residuals: Σ residual-buffer bytes × peak in-flight microbatches
    ///   (from the schedule) — measured `Residuals`.
    pub fn build(
        manifest: &ArtifactManifest,
        snapshots: &[MemorySnapshot],
        peak_inflight: &[u64],
        opt_shard: u64,
    ) -> anyhow::Result<Self> {
        if snapshots.len() != manifest.stages.len() {
            anyhow::bail!("{} snapshots for {} stages", snapshots.len(), manifest.stages.len());
        }
        let mut rows = Vec::new();
        for (i, st) in manifest.stages.iter().enumerate() {
            let snap = &snapshots[i];
            let fwd = manifest.executable(&st.fwd)?;
            let param_bytes: u64 =
                fwd.inputs.iter().filter(|b| b.role == "param").map(|b| b.bytes()).sum();
            let res_bytes: u64 =
                fwd.outputs.iter().filter(|b| b.role == "residual").map(|b| b.bytes()).sum();

            rows.push(ValidationRow {
                name: "params".into(),
                stage: st.stage,
                predicted_bytes: param_bytes,
                measured_bytes: snap.peak_of(MemTag::Params),
            });
            rows.push(ValidationRow {
                name: "gradients".into(),
                stage: st.stage,
                predicted_bytes: param_bytes, // fp32 grads of fp32 params
                measured_bytes: snap.peak_of(MemTag::Gradients),
            });
            rows.push(ValidationRow {
                name: "optimizer".into(),
                stage: st.stage,
                predicted_bytes: 2 * param_bytes / opt_shard,
                measured_bytes: snap.peak_of(MemTag::OptimizerM)
                    + snap.peak_of(MemTag::OptimizerV),
            });
            rows.push(ValidationRow {
                name: "residuals".into(),
                stage: st.stage,
                predicted_bytes: res_bytes * peak_inflight[i],
                measured_bytes: snap.peak_of(MemTag::Residuals),
            });
        }
        Ok(Self { rows })
    }

    /// Worst |ratio − 1| across rows.
    pub fn max_error(&self) -> f64 {
        self.rows
            .iter()
            .map(|r| (r.ratio() - 1.0).abs())
            .fold(0.0, f64::max)
    }

    pub fn render(&self) -> String {
        let mut t = crate::report::Table::new(
            "E3: analytical prediction vs measured bytes",
            &["stage", "quantity", "predicted", "measured", "ratio"],
        );
        for r in &self.rows {
            t.row(vec![
                r.stage.to_string(),
                r.name.clone(),
                crate::report::fmt_bytes(r.predicted_bytes),
                crate::report::fmt_bytes(r.measured_bytes),
                format!("{:.3}", r.ratio()),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_and_tolerance() {
        let r = ValidationRow {
            name: "x".into(),
            stage: 0,
            predicted_bytes: 100,
            measured_bytes: 105,
        };
        assert!((r.ratio() - 1.05).abs() < 1e-12);
        assert!(r.within(0.10));
        assert!(!r.within(0.01));
    }

    #[test]
    fn zero_prediction_edge() {
        let r = ValidationRow { name: "x".into(), stage: 0, predicted_bytes: 0, measured_bytes: 0 };
        assert!(r.within(0.01));
        let r = ValidationRow { name: "x".into(), stage: 0, predicted_bytes: 0, measured_bytes: 5 };
        assert!(!r.within(0.5));
    }
}
