//! Micro-benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations, reports mean / p50 / p95 / throughput. Used by every
//! `benches/*.rs` target (`harness = false`).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        );
    }

    /// Mean iterations per second.
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Benchmark `f`, auto-scaling the iteration count to ~`budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    let mut warm_iters = 0u64;
    while t0.elapsed() < budget / 10 {
        f();
        warm_iters += 1;
        if warm_iters >= 1_000_000 {
            break;
        }
    }
    let per_iter = (t0.elapsed().as_nanos() as f64 / warm_iters as f64).max(1.0);
    let target_iters = ((budget.as_nanos() as f64 / per_iter) as u64).clamp(5, 1_000_000);

    // Timed samples (batch small ops to reduce timer noise).
    let batch = (100.0 / per_iter).max(1.0) as u64;
    let samples = (target_iters / batch).clamp(5, 10_000);
    let mut times: Vec<f64> = Vec::with_capacity(samples as usize);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        times.push(t.elapsed().as_nanos() as f64 / batch as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters: samples * batch,
        mean_ns: mean,
        p50_ns: times[times.len() / 2],
        p95_ns: times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)],
        min_ns: times[0],
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box wrapper).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", Duration::from_millis(50), || {
            black_box((0..100u64).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("µs"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
