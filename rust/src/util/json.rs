//! Minimal JSON parser — enough for `artifacts/manifest.json` (objects,
//! arrays, strings, numbers, booleans, null; UTF-8; `\uXXXX` escapes).

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser { s: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            anyhow::bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> anyhow::Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow::anyhow!("missing key: {key}")),
            _ => anyhow::bail!("get({key}) on non-object"),
        }
    }

    /// `get` that tolerates absence (returns None).
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key).filter(|v| !matches!(v, Json::Null)),
            _ => None,
        }
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_u64(&self) -> anyhow::Result<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as u64),
            other => anyhow::bail!("expected unsigned integer, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => anyhow::bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => anyhow::bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => anyhow::bail!("expected array, got {other:?}"),
        }
    }

    /// Serialize to indented, line-diffable JSON: 2-space indent, one array
    /// element / object member per line, keys in `BTreeMap` order. This is
    /// the canonical golden-snapshot encoding of the scenario suite —
    /// deterministic byte-for-byte for equal values.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.pretty_into(&mut out, 0);
        out
    }

    fn pretty_into(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    push_indent(out, depth + 1);
                    v.pretty_into(out, depth + 1);
                    out.push_str(if i + 1 < a.len() { ",\n" } else { "\n" });
                }
                push_indent(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    push_indent(out, depth + 1);
                    out.push_str(&escape(k));
                    out.push_str(": ");
                    v.pretty_into(out, depth + 1);
                    out.push_str(if i + 1 < m.len() { ",\n" } else { "\n" });
                }
                push_indent(out, depth);
                out.push('}');
            }
            other => out.push_str(&other.dump()),
        }
    }

    /// Serialize back to compact JSON (used by tests and report export).
    pub fn dump(&self) -> String {
        match self {
            Json::Null => "null".into(),
            Json::Bool(b) => b.to_string(),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            Json::Str(s) => escape(s),
            Json::Arr(a) => {
                format!("[{}]", a.iter().map(|v| v.dump()).collect::<Vec<_>>().join(","))
            }
            Json::Obj(m) => format!(
                "{{{}}}",
                m.iter()
                    .map(|(k, v)| format!("{}:{}", escape(k), v.dump()))
                    .collect::<Vec<_>>()
                    .join(",")
            ),
        }
    }
}

fn push_indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.s
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek()? != c {
            anyhow::bail!(
                "expected '{}' at byte {}, got '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => anyhow::bail!("unexpected character '{}' at byte {}", c as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => anyhow::bail!("expected ',' or ']', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.s.len() {
                                anyhow::bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.s[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => anyhow::bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // Multi-byte UTF-8: find the full char from the source.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let chunk = std::str::from_utf8(&self.s[start..start + len])?;
                    out.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.s.len()
            && matches!(self.s[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
        assert!(v.opt("c").is_none());
        assert!(v.opt("missing").is_none());
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""line\nquote\" uA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "line\nquote\" uA");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo→""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo→");
    }

    #[test]
    fn pretty_roundtrips_and_indents() {
        let src = r#"{"arr":[1,2.5,"x"],"b":false,"empty":[],"n":null,"o":{"k":3}}"#;
        let v = Json::parse(src).unwrap();
        let pretty = v.pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.starts_with("{\n  \"arr\": [\n    1,\n"));
        assert!(pretty.contains("\"empty\": []"));
        assert!(pretty.ends_with('}'));
        // Scalars stay compact.
        assert_eq!(Json::Num(4.0).pretty(), "4");
    }

    #[test]
    fn dump_roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":false,"n":null,"o":{"k":3}}"#;
        let v = Json::parse(src).unwrap();
        let again = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse("[1]").unwrap();
        assert!(v.get("x").is_err());
        assert!(v.as_str().is_err());
        assert!(Json::parse("1.5").unwrap().as_u64().is_err());
    }
}
