//! Small self-contained utilities: a JSON parser (for the artifact manifest),
//! a deterministic RNG (SplitMix64 / xoshiro256**), and a micro-benchmark
//! harness — the repo builds fully offline with no external crates beyond
//! `xla` and `anyhow`.

pub mod bench;
pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Rng64;
