//! Deterministic RNG: SplitMix64 seeding + xoshiro256** core. No external
//! crates; identical across platforms (used by the synthetic corpus, the
//! fragmentation workloads and the property tests).

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng64 {
    s: [u64; 4],
}

impl Rng64 {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)` (Lemire reduction; `n > 0`).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[-a, a)`.
    pub fn f32_sym(&mut self, a: f32) -> f32 {
        (self.f64() as f32 * 2.0 - 1.0) * a
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng64::new(1);
        let mut b = Rng64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng64::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng64::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
