//! Golden regression tests pinning the paper's Table 6 / Table 8 (and the
//! §5 activation formulas) **per-component byte values** through the ledger
//! subsystem. These literals were derived from the paper's closed forms
//! before the ledger refactor; any silent drift in the component algebra
//! fails here with the exact byte delta.

use dsmem::analysis::{DeviceMemoryReport, MemoryModel, Overheads, ZeroStrategy};
use dsmem::config::{ActivationConfig, CaseStudy};
use dsmem::ledger::{Component, ComponentGroup};

fn mm() -> MemoryModel {
    let cs = CaseStudy::paper();
    MemoryModel::new(&cs.model, &cs.parallel, cs.dtypes)
}

// Table 6 parameter counts (BF16 → ×2 bytes).
const T6_DENSE_PARAMS: u64 = 429_719_552; // "Non-MoE Part"
const T6_MOE_PARAMS: u64 = 5_820_645_376; // "MoE"
const T6_TOTAL_PARAMS: u64 = 6_250_364_928; // "Total"

// Table 8 sharded parameter count: dense/DP32 + moe/EDP8.
const T8_SHARDED_DENSE: u64 = T6_DENSE_PARAMS / 32; // 13,428,736
const T8_SHARDED_MOE: u64 = T6_MOE_PARAMS / 8; // 727,580,672

#[test]
fn golden_table6_component_bytes() {
    let dev = mm().device_static_params();
    let l = dev.ledger();
    assert_eq!(l.get(Component::ParamsDense), 2 * T6_DENSE_PARAMS); // 859,439,104
    assert_eq!(l.get(Component::ParamsMoe), 2 * T6_MOE_PARAMS); // 11,641,290,752
    assert_eq!(l.total(), 2 * T6_TOTAL_PARAMS); // 12,500,729,856
    assert_eq!(l.total(), dev.total_bytes());
}

#[test]
fn golden_table8_per_component_bytes() {
    // Every Table 8 row, exact bytes per ledger component:
    //   params: BF16 (2 B);  grads: FP32 (4 B);  optimizer: 8 B/param.
    let zr = mm().zero_report();
    assert_eq!(zr.sharded_params, T8_SHARDED_DENSE + T8_SHARDED_MOE); // 741,009,408

    let full_g = 4 * T6_TOTAL_PARAMS; // 25,001,459,712
    let full_o = 8 * T6_TOTAL_PARAMS; // 50,002,919,424
    let sh = T8_SHARDED_DENSE + T8_SHARDED_MOE;

    let expect = [
        // (strategy, dense, moe, grads, optimizer)
        (ZeroStrategy::None, 2 * T6_DENSE_PARAMS, 2 * T6_MOE_PARAMS, full_g, full_o),
        (ZeroStrategy::Os, 2 * T6_DENSE_PARAMS, 2 * T6_MOE_PARAMS, full_g, 8 * sh),
        (ZeroStrategy::OsG, 2 * T6_DENSE_PARAMS, 2 * T6_MOE_PARAMS, 4 * sh, 8 * sh),
        (ZeroStrategy::OsGParams, 2 * T8_SHARDED_DENSE, 2 * T8_SHARDED_MOE, 4 * sh, 8 * sh),
    ];
    for (z, dense, moe, g, o) in expect {
        let l = zr.row(z).ledger();
        assert_eq!(l.get(Component::ParamsDense), dense, "{z:?} dense");
        assert_eq!(l.get(Component::ParamsMoe), moe, "{z:?} moe");
        assert_eq!(l.get(Component::Gradients), g, "{z:?} grads");
        assert_eq!(l.get(Component::OptimizerStates), o, "{z:?} optimizer");
        assert_eq!(l.total(), zr.row(z).total_bytes(), "{z:?} total");
    }
    // Headline totals (paper: 81.54 / 40.46 / 19.92 / 9.66 GB):
    // None = 14 B/param × 6,250,364,928; os+g+params = 14 B × 741,009,408.
    assert_eq!(zr.row(ZeroStrategy::None).total_bytes(), 14 * T6_TOTAL_PARAMS);
    assert_eq!(zr.row(ZeroStrategy::OsGParams).total_bytes(), 14 * sh);
}

#[test]
fn golden_activation_component_bytes_b1() {
    // §5 closed forms at b=1, s=4096, SP=TP=2, AC None, 4-layer stage:
    //   attention = 10bsh + 8bs(dcq+dc) + 16bs·dh·nh + 8bs·dhr·nh + 10b·nh·s²
    //   router    = 16bsN + 8bsN_r
    //   moe-mlp   = the remaining MoE-tape terms.
    let mm = mm();
    let act = ActivationConfig::paper(1);
    let rep = mm.activation_report(&act);
    let l = rep.stage_ledger(act.recompute);
    assert_eq!(l.get(Component::ActivationAttention), 23_177_723_904);
    assert_eq!(l.get(Component::ActivationRouter), 17_039_360);
    assert_eq!(l.get(Component::ActivationMoeMlp), 1_476_395_008);
    assert_eq!(l.get(Component::ActivationDenseMlp), 0);
    assert_eq!(l.get(Component::ActivationEmbedding), 0);
    assert_eq!(l.total(), 24_671_158_272);
    assert_eq!(l.total(), rep.total_stage_bytes(act.recompute));
}

#[test]
fn golden_end_to_end_report_is_bit_identical_to_flat_sums() {
    // The full per-device report at the paper midpoint overheads, ZeRO None:
    // allocated = P+G+O (Table 8 row 1) + activations (Table 10, b=1), then
    // comm buffers (1.4 GiB) and fragmentation (15% of allocated).
    let mm = mm();
    let act = ActivationConfig::paper(1);
    let ov = Overheads::paper_midpoint();
    let rep = DeviceMemoryReport::build(&mm, &act, ZeroStrategy::None, ov);
    let allocated: u64 = 87_505_108_992 + 24_671_158_272; // = 112,176,267,264
    assert_eq!(
        rep.ledger.group_total(ComponentGroup::Params)
            + rep.ledger.get(Component::Gradients)
            + rep.ledger.get(Component::OptimizerStates)
            + rep.ledger.group_total(ComponentGroup::Activation),
        allocated
    );
    assert_eq!(rep.comm_buffer_bytes(), (1.4 * dsmem::GIB) as u64);
    assert_eq!(rep.fragmentation_bytes(), ov.fragmentation_bytes(allocated));
    assert_eq!(
        rep.total_bytes(),
        allocated + (1.4 * dsmem::GIB) as u64 + ov.fragmentation_bytes(allocated)
    );
}

#[test]
fn golden_v2_lite_total_params_in_published_range() {
    // DeepSeek-V2-Lite advertises 15.7B total parameters; our census (with
    // the direct-W^Q query path) must land on it.
    let m = dsmem::config::ModelConfig::deepseek_v2_lite();
    let census = dsmem::model::ModelParams::build(&m, dsmem::model::CountMode::Strict);
    let total = census.total() as f64 / 1e9;
    assert!((15.2..16.2).contains(&total), "v2-lite total = {total} B");
}
