//! Cross-module integration: the analytical model end-to-end — every paper
//! number flows config → model → analysis → report, plus cross-checks the
//! unit tests can't express (tables agreeing with each other).

use dsmem::analysis::{MemoryModel, Overheads, StagePlan, StageSplit, ZeroStrategy};
use dsmem::config::{
    ActivationConfig, CaseStudy, Dtype, ModelConfig, ParallelConfig, RecomputePolicy,
};
use dsmem::model::CountMode;
use dsmem::report::tables::paper_table;

fn paper_mm() -> MemoryModel {
    let cs = CaseStudy::paper();
    MemoryModel::new(&cs.model, &cs.parallel, cs.dtypes)
}

#[test]
fn tables_3_and_4_agree_on_totals() {
    let mm = paper_mm();
    assert_eq!(mm.param_table().total_params(), mm.stage_plan().total_params());
}

#[test]
fn table6_replication_overhead_vs_table4() {
    // Sum of per-device params over one stage's (TP × EP-plane) devices must
    // exceed the stage's logical total (norms, routers and shared experts
    // are replicated) but only by the replicated fraction.
    let cs = CaseStudy::paper();
    let mm = paper_mm();
    let plan = mm.stage_plan();
    let dev = mm.device_static_params();
    let devices = cs.parallel.devices_per_stage();
    let summed = dev.non_moe_params() * devices
        - dev.mla * devices // MLA is TP-split: count once per TP group
        + dev.mla * devices
        + dev.moe_params() * devices;
    // Simpler invariant: per-device total × devices ≥ stage params.
    assert!(summed >= plan.stages[1].params);
    // And the TP-partitioned parts alone reassemble exactly:
    // MLA split set × tp + replicated parts... asserted at module level; here
    // just sanity-check the per-device total is less than the whole stage.
    assert!(dev.total_params() < plan.stages[1].params);
}

#[test]
fn zero_table_composes_with_activation_table() {
    // DeviceMemoryReport must equal ZeroRow + activation bytes exactly when
    // overheads are disabled.
    let mm = paper_mm();
    let act = ActivationConfig::paper(2);
    for z in ZeroStrategy::ALL {
        let rep = mm.device_memory(&act, z, Overheads::none());
        let zr = mm.zero_report();
        let row = zr.row(z);
        let ar = mm.activation_report(&act);
        assert_eq!(
            rep.total_bytes(),
            row.total_bytes() + ar.total_stage_bytes(act.recompute),
            "{z:?}"
        );
    }
}

#[test]
fn v3_against_known_hf_config_totals() {
    // Cross-check our parameter algebra against the publicly known totals:
    // DeepSeek-v3 = 671B total / ~37B activated. We verify total & per-token
    // activated params (MLA + shared + top-8 routed + embeddings).
    let m = ModelConfig::deepseek_v3();
    let mm = paper_mm();
    assert_eq!(mm.param_table().total_params(), 671_026_522_112);

    let activated_moe_layer = dsmem::model::moe::router_params(&m)
        + dsmem::model::moe::params_per_expert(&m)
            * (m.num_experts_per_tok + m.n_shared_experts);
    let activated = dsmem::model::embedding::embedding_params(&m)
        + dsmem::model::embedding::head_params(&m)
        + (dsmem::model::mla::params_per_layer(&m, CountMode::PaperCompat) + 16384)
            * m.num_hidden_layers
        + dsmem::model::dense::ffn_params_per_layer(&m) * m.first_k_dense
        + activated_moe_layer * m.num_moe_layers();
    let b = activated as f64 / 1e9;
    assert!((36.0..39.0).contains(&b), "activated ≈ {b} B, expected ~37 B");
}

#[test]
fn every_table_renders_for_v2_and_mini() {
    for model in [ModelConfig::deepseek_v2(), ModelConfig::mini()] {
        let mut cs = CaseStudy::paper();
        // Pick parallelism valid for each model.
        cs.parallel = if model.name == "deepseek-mini" {
            ParallelConfig { dp: 1, tp: 1, pp: 2, ep: 1, etp: 1 }
        } else {
            ParallelConfig { dp: 16, tp: 2, pp: 10, ep: 8, etp: 1 }
        };
        if model.name == "deepseek-mini" {
            cs.activation.sp = 1;
            cs.activation.seq_len = 128;
        }
        cs.model = model;
        cs.validate().unwrap();
        for n in 1..=10u8 {
            let t = paper_table(&cs, n).unwrap();
            assert!(!t.rows.is_empty(), "table {n} empty for {}", cs.model.name);
        }
    }
}

#[test]
fn recompute_orderings_hold_everywhere() {
    // AC Full ≤ Selective ≤ None for every (model, b).
    for model in [ModelConfig::deepseek_v3(), ModelConfig::deepseek_v2()] {
        let cs = CaseStudy::paper();
        let mut parallel = cs.parallel;
        if StageSplit::FrontLoaded
            .layer_counts(model.num_hidden_layers, parallel.pp)
            .is_err()
        {
            // v2's 60 layers split front-loaded over 16 stages would leave an
            // empty last stage; PP10 is its natural even split.
            parallel.pp = 10;
        }
        let mm = MemoryModel::new(&model, &parallel, cs.dtypes);
        for b in [1, 2, 4, 8] {
            let rep = mm.activation_report(&ActivationConfig::paper(b));
            let none = rep.total_stage_bytes(RecomputePolicy::None);
            let sel = rep.mla_stage_bytes(RecomputePolicy::SelectiveAttention)
                + rep.moe_stage_bytes(RecomputePolicy::SelectiveAttention);
            let full = rep.total_stage_bytes(RecomputePolicy::Full);
            assert!(full < sel && sel < none, "{} b={b}", model.name);
        }
    }
}

#[test]
fn stage_plans_cover_all_layers_for_many_pp() {
    let m = ModelConfig::deepseek_v3();
    for pp in [1u64, 2, 4, 8, 16] {
        for split in [StageSplit::FrontLoaded, StageSplit::Balanced] {
            let plan = StagePlan::build(&m, pp, split, CountMode::PaperCompat);
            assert_eq!(plan.total_params(), 671_026_522_112, "pp={pp}");
            let layers: u64 = plan.stages.iter().map(|s| s.num_layers).sum();
            assert_eq!(layers, 61);
        }
    }
}

#[test]
fn paper_gb_columns_within_rounding() {
    // Every GB the paper prints must match ours within 1 GiB (the paper
    // rounds aggressively).
    let mm = paper_mm();
    let plan = mm.stage_plan();
    let checks = [
        (plan.stage_bytes(0, Dtype::Bf16), 26.0),
        (plan.stage_bytes(1, Dtype::Bf16), 86.0),
        (plan.stage_bytes(15, Dtype::Bf16), 23.0),
    ];
    for (bytes, paper_gb) in checks {
        let gib = bytes as f64 / dsmem::GIB;
        assert!((gib - paper_gb).abs() < 1.0, "{gib} vs paper {paper_gb}");
    }
}
