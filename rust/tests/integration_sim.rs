//! Integration: the cluster simulator against the analytical model (the E2
//! bridge), across schedules, ZeRO strategies and recompute policies.

use dsmem::analysis::{ActivationReport, MemoryModel, ZeroStrategy};
use dsmem::config::{ActivationConfig, CaseStudy, RecomputePolicy};
use dsmem::sim::{MemClass, Schedule, ScheduleKind, SimEngine};

fn mm() -> MemoryModel {
    let cs = CaseStudy::paper();
    MemoryModel::new(&cs.model, &cs.parallel, cs.dtypes)
}

#[test]
fn sim_activation_peak_equals_analytic_for_every_stage_and_schedule() {
    let mm = mm();
    let act = ActivationConfig::paper(1);
    let plan = mm.stage_plan();
    for kind in [ScheduleKind::GPipe, ScheduleKind::OneFOneB] {
        let eng = SimEngine::new(&mm, act, ZeroStrategy::OsG);
        let res = eng.run(kind, 16).unwrap();
        let sched = Schedule::build(kind, 16, 16).unwrap();
        for st in &res.stages {
            let ar = ActivationReport::build(
                &mm.model,
                &mm.parallel,
                &act,
                plan.stages[st.stage as usize].num_layers,
            );
            // Dense stages charge MLA-only for dense layers (documented
            // conservative choice) — recompute the engine's per-mb figure.
            let per_mb = ar.mla.device_bytes(act.recompute)
                * plan.stages[st.stage as usize].num_layers
                + ar.moe.device_bytes(act.recompute)
                    * plan.stages[st.stage as usize].moe_layers;
            assert_eq!(
                st.timeline.peak(MemClass::Activations),
                per_mb * sched.analytic_inflight(st.stage),
                "{kind:?} stage {}",
                st.stage
            );
        }
    }
}

#[test]
fn static_classes_match_zero_rows_scaled() {
    // Params/grads/optimizer in the sim must track the ZeRO table for the
    // analysed (heaviest) stage.
    let mm = mm();
    let act = ActivationConfig::paper(1);
    for z in ZeroStrategy::ALL {
        let eng = SimEngine::new(&mm, act, z);
        let res = eng.run(ScheduleKind::OneFOneB, 8).unwrap();
        let zr = mm.zero_report();
        let row = zr.row(z);
        let st = &res.stages[1]; // stages 1..14 are the analysed archetype
        assert_eq!(st.timeline.peak(MemClass::Params), row.params_bytes, "{z:?}");
        assert_eq!(st.timeline.peak(MemClass::Gradients), row.gradient_bytes);
        assert_eq!(st.timeline.peak(MemClass::Optimizer), row.optimizer_bytes);
    }
}

#[test]
fn full_recompute_beats_gpipe_none_by_orders_of_magnitude() {
    let mm = mm();
    let none = SimEngine::new(&mm, ActivationConfig::paper(1), ZeroStrategy::OsG)
        .run(ScheduleKind::GPipe, 16)
        .unwrap();
    let full = SimEngine::new(&mm, ActivationConfig::paper_full_recompute(1), ZeroStrategy::OsG)
        .run(ScheduleKind::GPipe, 16)
        .unwrap();
    let a = none.peak_stage().timeline.peak(MemClass::Activations);
    let b = full.peak_stage().timeline.peak(MemClass::Activations);
    assert!(a / b > 50, "AC none {a} vs full {b}");
}

#[test]
fn interleaved_holds_more_than_plain_1f1b() {
    // Megatron's interleaved schedule trades activation memory for bubble:
    // with enough microbatches (m ≥ warmup bound), the first stage holds
    // (p−1)·2 + (v−1)·p + 1 chunk-units vs 1F1B's p full microbatches.
    let mm = mm();
    let act = ActivationConfig::paper(1);
    let eng = SimEngine::new(&mm, act, ZeroStrategy::OsG);
    let plain = eng.run(ScheduleKind::OneFOneB, 32).unwrap();
    let inter = eng.run(ScheduleKind::Interleaved1F1B { chunks: 2 }, 32).unwrap();
    assert!(
        inter.stages[0].timeline.peak(MemClass::Activations)
            > plain.stages[0].timeline.peak(MemClass::Activations),
        "inter {} vs plain {}",
        inter.stages[0].timeline.peak(MemClass::Activations),
        plain.stages[0].timeline.peak(MemClass::Activations),
    );
}

#[test]
fn comm_buffers_stay_in_paper_band() {
    // §6: transient comm buffers 0.8–2 GB per device.
    let mm = mm();
    let act = ActivationConfig::paper(1);
    let eng = SimEngine::new(&mm, act, ZeroStrategy::OsG);
    let res = eng.run(ScheduleKind::OneFOneB, 8).unwrap();
    for st in &res.stages {
        let peak = st.timeline.peak(MemClass::CommBuffers) as f64 / dsmem::GIB;
        assert!((0.1..=2.0).contains(&peak), "stage {} buffers {peak} GiB", st.stage);
    }
}

#[test]
fn fragmentation_replay_stays_in_paper_band() {
    let mm = mm();
    let mut eng = SimEngine::new(&mm, ActivationConfig::paper(1), ZeroStrategy::OsG);
    eng.simulate_allocator = true;
    let res = eng.run(ScheduleKind::OneFOneB, 8).unwrap();
    for st in res.stages.iter().take(4) {
        let f = st.alloc_stats.unwrap().fragmentation();
        assert!((0.0..0.35).contains(&f), "stage {} frag {f}", st.stage);
    }
}
