//! Integration: the cluster simulator against the analytical model (the E2
//! bridge), across every registered schedule, ZeRO strategies and recompute
//! policies — asserted **per ledger component**, not just in total.

use dsmem::analysis::stages::StageSplit;
use dsmem::analysis::total::Overheads;
use dsmem::analysis::{
    ActivationReport, ClusterMemoryAtlas, MemoryModel, StageInflight, ZeroStrategy,
};
use dsmem::config::{ActivationConfig, CaseStudy};
use dsmem::ledger::{Component, ComponentGroup, MemoryLedger};
use dsmem::model::CountMode;
use dsmem::planner::{Candidate, Evaluator};
use dsmem::schedule::{registry, Schedule, ScheduleSpec};
use dsmem::sim::SimEngine;

fn mm() -> MemoryModel {
    let cs = CaseStudy::paper();
    MemoryModel::new(&cs.model, &cs.parallel, cs.dtypes)
}

/// The engine's per-microbatch component ledger for one stage: MLA for every
/// layer, MoE for the stage's MoE layers (dense stages charge MLA only —
/// documented conservative choice).
fn stage_mb_ledger(mm: &MemoryModel, act: &ActivationConfig, stage: usize) -> MemoryLedger {
    let plan = mm.stage_plan();
    let ar = ActivationReport::build(
        &mm.model,
        &mm.parallel,
        act,
        plan.stages[stage].num_layers,
    );
    ar.mla
        .ledger(act.recompute)
        .scale(plan.stages[stage].num_layers)
        .merged(&ar.moe.ledger(act.recompute).scale(plan.stages[stage].moe_layers))
}

#[test]
fn sim_activation_peak_equals_analytic_for_every_stage_and_schedule() {
    // The E2 bridge, per stage and per ledger component, for EVERY
    // registered schedule: the replayed peak of each activation component
    // must equal the per-unit component tape times the schedule's analytic
    // in-flight bound, and the replayed in-flight count must equal the
    // analytic one.
    let mm = mm();
    let act = ActivationConfig::paper(1);
    let m = 32; // admits every registered schedule at p=16 (dualpipe: m = 2p)
    let mut covered = 0;
    for spec in registry() {
        let sched = spec.resolve();
        assert!(sched.validate(16, m).is_ok(), "{} rejects the paper shape", spec.name());
        let eng = SimEngine::new(&mm, act, ZeroStrategy::OsG);
        let res = eng.run(spec, m).unwrap();
        let schedule = Schedule::build(spec, 16, m).unwrap();
        let unit_div = sched.units_per_microbatch().max(1);
        for st in &res.stages {
            let per_unit = stage_mb_ledger(&mm, &act, st.stage as usize).div(unit_div);
            let units = schedule.analytic_inflight(st.stage);
            assert_eq!(st.peak_inflight, units, "{} stage {}", spec.name(), st.stage);
            for (c, bytes) in per_unit.iter() {
                if c.group() != ComponentGroup::Activation {
                    continue;
                }
                assert_eq!(
                    st.timeline.peak(c),
                    bytes * units,
                    "{} stage {} component {}",
                    spec.name(),
                    st.stage,
                    c.name()
                );
            }
            // The group peak is the component sum at the peak (they rise and
            // fall together), so the total-wise bridge follows.
            assert_eq!(
                st.timeline.group_peak(ComponentGroup::Activation),
                per_unit.group_total(ComponentGroup::Activation) * units,
                "{} stage {}",
                spec.name(),
                st.stage
            );
        }
        covered += 1;
    }
    assert_eq!(covered, 5);
}

#[test]
fn sim_ledger_equals_planner_ledger_per_component_for_every_schedule() {
    // The planner side of the E2 bridge, component-wise: for every
    // registered schedule, the sim-replayed peak ledger at the *binding*
    // stage (the stage the planner now reports) must equal the Evaluator's
    // analytic ledger for the same candidate on every non-transient
    // component — params (dense & MoE, including DualPipe's ×2), gradients,
    // optimizer states and every activation component. (Comm buffers and
    // workspace are transient sim artifacts; fragmentation/KV-cache are
    // zero on both sides here.)
    let cs = CaseStudy::paper();
    let mm = mm();
    let act = ActivationConfig::paper(1);
    let m = 32;
    let ev = Evaluator::new(
        &cs.model,
        cs.dtypes,
        CountMode::PaperCompat,
        StageSplit::FrontLoaded,
        Overheads::none(),
        m,
    );
    for spec in registry() {
        let eng = SimEngine::new(&mm, act, ZeroStrategy::OsG);
        let res = eng.run(spec, m).unwrap();
        let point = ev.evaluate(&Candidate {
            parallel: cs.parallel,
            act,
            zero: ZeroStrategy::OsG,
            schedule: spec,
        });
        let sim = res.stages[point.binding_stage as usize].peak_ledger();
        for c in Component::ALL {
            if matches!(c.group(), ComponentGroup::CommBuffer | ComponentGroup::Workspace) {
                continue;
            }
            assert_eq!(
                sim.get(c),
                point.ledger.get(c),
                "{} component {}",
                spec.name(),
                c.name()
            );
        }
        // Totals follow from the component equality.
        assert_eq!(
            res.stages[point.binding_stage as usize]
                .timeline
                .group_peak(ComponentGroup::Activation),
            point.activation_bytes(),
            "{}",
            spec.name()
        );
    }
}

#[test]
fn sim_peak_ledger_equals_atlas_on_every_stage_for_every_schedule() {
    // The tentpole bridge: for EVERY registered schedule and EVERY pipeline
    // stage, the sim-replayed peak ledger must equal the cluster atlas's
    // entry per non-transient component — statics from that stage's own
    // ZeRO report, activations from that stage's tape times its analytic
    // in-flight count.
    let mm = mm();
    let act = ActivationConfig::paper(1);
    let m = 32;
    let mut covered = 0;
    for spec in registry() {
        let eng = SimEngine::new(&mm, act, ZeroStrategy::OsG);
        let res = eng.run(spec, m).unwrap();
        let inflight = StageInflight::for_schedule(spec, 16, m).unwrap();
        let atlas = ClusterMemoryAtlas::build(
            &mm,
            &act,
            ZeroStrategy::OsG,
            Overheads::none(),
            &inflight,
        )
        .unwrap();
        assert_eq!(atlas.entries.len(), res.stages.len());
        for st in &res.stages {
            let entry = &atlas.entries[st.stage as usize];
            assert_eq!(st.peak_inflight, entry.inflight_units, "{} stage {}", spec.name(), st.stage);
            let sim = st.peak_ledger();
            for c in Component::ALL {
                if matches!(c.group(), ComponentGroup::CommBuffer | ComponentGroup::Workspace) {
                    continue;
                }
                assert_eq!(
                    sim.get(c),
                    entry.ledger.get(c),
                    "{} stage {} component {}",
                    spec.name(),
                    st.stage,
                    c.name()
                );
            }
        }
        covered += 1;
    }
    assert_eq!(covered, 5);
}

#[test]
fn sim_statics_are_exact_per_stage_zero_reports() {
    // Every stage's static classes come from that stage's own layer census
    // through its own ZeRO report — the retired approximation ratio-scaled
    // the archetype stage's rows instead.
    use dsmem::analysis::device::DeviceStaticParams;
    use dsmem::analysis::ZeroReport;
    let mm = mm();
    let act = ActivationConfig::paper(1);
    let plan = mm.stage_plan();
    for z in ZeroStrategy::ALL {
        let eng = SimEngine::new(&mm, act, z);
        let res = eng.run(ScheduleSpec::OneFOneB, 8).unwrap();
        for st in &res.stages {
            let dev = DeviceStaticParams::for_stage(
                &mm.model,
                &mm.parallel,
                &plan,
                st.stage as usize,
                mm.dtypes.weight,
            );
            let zr = ZeroReport::build(&dev, &mm.parallel, mm.dtypes);
            let row = zr.row(z);
            assert_eq!(
                st.timeline.peak(Component::ParamsDense),
                row.params_dense_bytes,
                "{z:?} stage {}",
                st.stage
            );
            assert_eq!(
                st.timeline.peak(Component::ParamsMoe),
                row.params_moe_bytes,
                "{z:?} stage {}",
                st.stage
            );
            assert_eq!(st.timeline.peak(Component::Gradients), row.gradient_bytes);
            assert_eq!(st.timeline.peak(Component::OptimizerStates), row.optimizer_bytes);
        }
    }
}

#[test]
fn static_classes_match_zero_rows_scaled() {
    // Params (dense + MoE) / grads / optimizer in the sim must track the
    // ZeRO table for the analysed (heaviest) stage, component for component.
    let mm = mm();
    let act = ActivationConfig::paper(1);
    for z in ZeroStrategy::ALL {
        let eng = SimEngine::new(&mm, act, z);
        let res = eng.run(ScheduleSpec::OneFOneB, 8).unwrap();
        let zr = mm.zero_report();
        let row = zr.row(z);
        let st = &res.stages[1]; // stages 1..14 are the analysed archetype
        assert_eq!(st.timeline.peak(Component::ParamsDense), row.params_dense_bytes, "{z:?}");
        assert_eq!(st.timeline.peak(Component::ParamsMoe), row.params_moe_bytes, "{z:?}");
        assert_eq!(st.timeline.group_peak(ComponentGroup::Params), row.params_bytes, "{z:?}");
        assert_eq!(st.timeline.peak(Component::Gradients), row.gradient_bytes);
        assert_eq!(st.timeline.peak(Component::OptimizerStates), row.optimizer_bytes);
    }
}

#[test]
fn dualpipe_params_double_but_shards_do_not() {
    // DualPipe keeps both replicas' stage weights resident (params ×2, in
    // both partitions); gradient and optimizer shards stay single
    // (reduced/sharded across the mirrored pair).
    let mm = mm();
    let act = ActivationConfig::paper(1);
    let eng = SimEngine::new(&mm, act, ZeroStrategy::OsG);
    let res = eng.run(ScheduleSpec::DualPipe, 32).unwrap();
    let zr = mm.zero_report();
    let row = zr.row(ZeroStrategy::OsG);
    let st = &res.stages[1];
    assert_eq!(st.timeline.peak(Component::ParamsDense), 2 * row.params_dense_bytes);
    assert_eq!(st.timeline.peak(Component::ParamsMoe), 2 * row.params_moe_bytes);
    assert_eq!(st.timeline.peak(Component::Gradients), row.gradient_bytes);
    assert_eq!(st.timeline.peak(Component::OptimizerStates), row.optimizer_bytes);
}

#[test]
fn full_recompute_beats_gpipe_none_by_orders_of_magnitude() {
    let mm = mm();
    let none = SimEngine::new(&mm, ActivationConfig::paper(1), ZeroStrategy::OsG)
        .run(ScheduleSpec::GPipe, 16)
        .unwrap();
    let full = SimEngine::new(&mm, ActivationConfig::paper_full_recompute(1), ZeroStrategy::OsG)
        .run(ScheduleSpec::GPipe, 16)
        .unwrap();
    let a = none.peak_stage().timeline.group_peak(ComponentGroup::Activation);
    let b = full.peak_stage().timeline.group_peak(ComponentGroup::Activation);
    assert!(a / b > 50, "AC none {a} vs full {b}");
}

#[test]
fn interleaved_holds_more_than_plain_1f1b() {
    // Megatron's interleaved schedule trades activation memory for bubble:
    // with enough microbatches (m ≥ warmup bound), the first stage holds
    // (p−1)·2 + (v−1)·p + 1 chunk-units vs 1F1B's p full microbatches.
    let mm = mm();
    let act = ActivationConfig::paper(1);
    let eng = SimEngine::new(&mm, act, ZeroStrategy::OsG);
    let plain = eng.run(ScheduleSpec::OneFOneB, 32).unwrap();
    let inter = eng.run(ScheduleSpec::Interleaved1F1B { chunks: 2 }, 32).unwrap();
    assert!(
        inter.stages[0].timeline.group_peak(ComponentGroup::Activation)
            > plain.stages[0].timeline.group_peak(ComponentGroup::Activation),
        "inter {} vs plain {}",
        inter.stages[0].timeline.group_peak(ComponentGroup::Activation),
        plain.stages[0].timeline.group_peak(ComponentGroup::Activation),
    );
}

#[test]
fn comm_buffers_stay_in_paper_band() {
    // §6: transient comm buffers 0.8–2 GB per device (the engine clamps at
    // sim::COMM_BUFFER_CAP_BYTES = the top of the band).
    let mm = mm();
    let act = ActivationConfig::paper(1);
    let eng = SimEngine::new(&mm, act, ZeroStrategy::OsG);
    let res = eng.run(ScheduleSpec::OneFOneB, 8).unwrap();
    for st in &res.stages {
        let peak = st.timeline.peak(Component::CommBuffer) as f64 / dsmem::GIB;
        assert!((0.1..=2.0).contains(&peak), "stage {} buffers {peak} GiB", st.stage);
        assert!(
            st.timeline.peak(Component::CommBuffer) <= dsmem::sim::COMM_BUFFER_CAP_BYTES
        );
    }
}

#[test]
fn fragmentation_replay_stays_in_paper_band() {
    let mm = mm();
    let mut eng = SimEngine::new(&mm, ActivationConfig::paper(1), ZeroStrategy::OsG);
    eng.simulate_allocator = true;
    let res = eng.run(ScheduleSpec::OneFOneB, 8).unwrap();
    for st in res.stages.iter().take(4) {
        let f = st.alloc_stats.unwrap().fragmentation();
        assert!((0.0..0.35).contains(&f), "stage {} frag {f}", st.stage);
        // The peak ledger surfaces the same estimate in bytes.
        let stats = st.alloc_stats.unwrap();
        assert_eq!(
            st.peak_ledger().get(Component::Fragmentation),
            stats.peak_reserved - stats.peak_allocated,
            "stage {}",
            st.stage
        );
    }
}
