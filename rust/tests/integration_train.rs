//! Integration over the live runtime (requires `make artifacts`): loads the
//! AOT bundle, runs real pipeline training steps on CPU-PJRT, and checks
//! loss behaviour, determinism, schedule effects on residual residency, and
//! the E3 measured-vs-analytical validation.
//!
//! Each test skips (with a notice) when artifacts are absent, so `cargo
//! test` stays green on a fresh checkout.
//!
//! The whole file is additionally gated behind the `live` cargo feature:
//! compiling it needs the `xla` PJRT bindings, which the offline tier-1
//! environment does not provide (see Cargo.toml).
#![cfg(feature = "live")]

use dsmem::config::{LiveSchedule, TrainingConfig};
use dsmem::coordinator::PipelineCoordinator;
use dsmem::runtime::{ArtifactManifest, MemTag, Runtime};
use dsmem::schedule::{Schedule, ScheduleSpec};
use dsmem::trainer::{MemoryValidation, SyntheticCorpus};
use std::path::Path;
use std::sync::Arc;

fn artifacts() -> Option<ArtifactManifest> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(ArtifactManifest::load(dir).unwrap())
}

/// Load the runtime once *per test* (PjRtClient is Rc-based, so it cannot
/// cross test threads); tests that need several coordinators share one load.
fn load_runtime() -> Arc<Runtime> {
    Arc::new(Runtime::load(artifacts().unwrap()).unwrap())
}

fn mini_cfg(man: &ArtifactManifest) -> TrainingConfig {
    let mut cfg = TrainingConfig::mini_default();
    cfg.pp = man.pp;
    cfg.micro_batch = man.micro_batch;
    cfg.seq_len = man.seq_len;
    cfg.num_microbatches = 2;
    cfg.steps = 1;
    cfg
}

#[test]
fn manifest_total_params_matches_rust_mini_model() {
    let Some(man) = artifacts() else { return };
    // The manifest's parameter count must equal what the Rust-side shape
    // algebra predicts for ModelConfig::mini() (strict counting + the q/kv
    // LoRA norms live inside the per-layer tensors here).
    let m = dsmem::config::ModelConfig::mini();
    let census =
        dsmem::model::ModelParams::build(&m, dsmem::model::CountMode::PaperCompat);
    // PaperCompat double-counts the LoRA norms (they're real tensors once in
    // the artifacts), so subtract one copy per layer; add the final norm.
    let expected = census.total() - (m.q_lora_rank + m.kv_lora_rank) * m.num_hidden_layers
        + m.hidden_size;
    assert_eq!(man.total_params, expected);
}

#[test]
fn one_step_trains_and_validates_memory() {
    let Some(man) = artifacts() else { return };
    let cfg = mini_cfg(&man);
    let rt = load_runtime();
    let man = rt.manifest.clone();
    let mut coord = PipelineCoordinator::new(rt, cfg.clone()).unwrap();

    let mut corpus = SyntheticCorpus::new(man.vocab_size as u32, 4, 1);
    let data = corpus.step_batch(1, 2, (cfg.micro_batch * cfg.seq_len) as usize);
    let stats = coord.step(&data).unwrap();
    assert!(stats.loss.is_finite());
    // Untrained loss ≈ ln(V) = 7.62 for V=2048.
    assert!((6.5..9.0).contains(&stats.loss), "loss {}", stats.loss);

    let sched = Schedule::build(ScheduleSpec::OneFOneB, cfg.pp, cfg.num_microbatches).unwrap();
    let inflight: Vec<u64> = (0..cfg.pp).map(|s| sched.analytic_inflight(s)).collect();
    let val =
        MemoryValidation::build(&man, &coord.memory_snapshots(), &inflight, 1).unwrap();
    assert!(
        val.max_error() < 0.01,
        "measured vs analytical error {:.3}%\n{}",
        100.0 * val.max_error(),
        val.render()
    );
}

#[test]
fn loss_is_deterministic_for_fixed_seed() {
    let Some(man) = artifacts() else { return };
    let cfg = mini_cfg(&man);
    let shared = load_runtime();
    let run = |seed: u64| {
        let rt = shared.clone();
        let mut coord = PipelineCoordinator::new(rt, cfg.clone()).unwrap();
        let mut corpus = SyntheticCorpus::new(man.vocab_size as u32, 4, seed);
        let data = corpus.step_batch(1, 2, (cfg.micro_batch * cfg.seq_len) as usize);
        coord.step(&data).unwrap().loss
    };
    assert_eq!(run(7), run(7));
    assert_ne!(run(7), run(8));
}

#[test]
fn gpipe_residual_peak_exceeds_1f1b() {
    let Some(man) = artifacts() else { return };
    let mut cfg = mini_cfg(&man);
    cfg.num_microbatches = 4;

    let shared = load_runtime();
    let peak_res = |schedule: LiveSchedule| {
        let rt = shared.clone();
        let mut c = cfg.clone();
        c.schedule = schedule;
        let mut coord = PipelineCoordinator::new(rt, c).unwrap();
        let mut corpus = SyntheticCorpus::new(man.vocab_size as u32, 4, 3);
        let data = corpus.step_batch(1, 4, (cfg.micro_batch * cfg.seq_len) as usize);
        coord.step(&data).unwrap();
        coord.memory_snapshots()[0].peak_of(MemTag::Residuals)
    };
    let gpipe = peak_res(LiveSchedule::GPipe);
    let one_f = peak_res(LiveSchedule::OneFOneB);
    // Stage 0 under GPipe holds all 4 microbatches; under 1F1B only pp = 2.
    assert!(gpipe > one_f, "gpipe {gpipe} vs 1f1b {one_f}");
    assert_eq!(gpipe, 2 * one_f);
}

#[test]
fn verbose_activations_hold_intermediates() {
    let Some(man) = artifacts() else { return };
    if man.stages.iter().any(|s| s.fwd_verbose.is_none()) {
        eprintln!("skipping: artifacts built without verbose forwards");
        return;
    }
    let mut cfg = mini_cfg(&man);
    cfg.verbose_activations = true;
    let rt = load_runtime();
    let mut coord = PipelineCoordinator::new(rt, cfg.clone()).unwrap();
    let mut corpus = SyntheticCorpus::new(man.vocab_size as u32, 4, 5);
    let data = corpus.step_batch(1, 2, (cfg.micro_batch * cfg.seq_len) as usize);
    coord.step(&data).unwrap();
    let snaps = coord.memory_snapshots();
    // AC-None residency: intermediates were live alongside residuals.
    assert!(snaps[0].peak_of(MemTag::Intermediates) > snaps[0].peak_of(MemTag::Residuals));
}

#[test]
fn dp2_replicas_agree_after_all_reduce() {
    let Some(man) = artifacts() else { return };
    let mut cfg = mini_cfg(&man);
    cfg.dp = 2;
    let rt = load_runtime();
    let mut coord = PipelineCoordinator::new(rt, cfg.clone()).unwrap();
    let mut corpus = SyntheticCorpus::new(man.vocab_size as u32, 4, 9);
    let data = corpus.step_batch(2, 2, (cfg.micro_batch * cfg.seq_len) as usize);
    let stats = coord.step(&data).unwrap();
    assert!(stats.loss.is_finite());
}

#[test]
fn zero_os_halves_owned_optimizer_state() {
    let Some(man) = artifacts() else { return };
    let mut cfg = mini_cfg(&man);
    cfg.dp = 2;
    cfg.zero_os = true;
    let rt = load_runtime();
    let coord = PipelineCoordinator::new(rt, cfg).unwrap();
    let snaps = coord.memory_snapshots();
    let man2 = artifacts().unwrap();
    for (i, snap) in snaps.iter().enumerate() {
        let params = man2.stage_param_bytes(i).unwrap();
        let opt = snap.peak_of(MemTag::OptimizerM) + snap.peak_of(MemTag::OptimizerV);
        // Round-robin sharding over 2 replicas ≈ half the state (tensor
        // granularity → allow 60/40 skew).
        let frac = opt as f64 / (2 * params) as f64;
        assert!((0.3..0.7).contains(&frac), "stage {i}: {frac}");
    }
}
