//! Property tests (hand-rolled generator sweep — the offline build has no
//! proptest crate): randomized (model, parallel, activation) configurations
//! must uphold the analytical model's invariants.

use dsmem::analysis::{MemoryModel, StagePlan, StageSplit, ZeroStrategy};
use dsmem::config::{ActivationConfig, Dtype, DtypePolicy, ModelConfig, ParallelConfig, RecomputePolicy};
use dsmem::model::CountMode;
use dsmem::parallel::{build_groups, GroupKind, RankGrid};
use dsmem::util::Rng64;

const CASES: usize = 200;

/// Random valid model config (DeepSeek-shaped, divisibility respected).
fn random_model(rng: &mut Rng64) -> ModelConfig {
    let nh = [4u64, 8, 16, 32, 64, 128][rng.below(6) as usize];
    let l = rng.range(4, 80);
    ModelConfig {
        name: "random".into(),
        hidden_size: 64 * rng.range(2, 120),
        moe_intermediate_size: 64 * rng.range(1, 40),
        intermediate_size: 64 * rng.range(4, 300),
        qk_nope_head_dim: [32u64, 64, 128][rng.below(3) as usize],
        num_attention_heads: nh,
        q_lora_rank: 64 * rng.range(1, 30),
        qk_rope_head_dim: [16u64, 32, 64][rng.below(3) as usize],
        kv_lora_rank: 64 * rng.range(1, 10),
        n_routed_experts: [8u64, 16, 32, 64, 128, 256][rng.below(6) as usize],
        n_shared_experts: rng.range(1, 3),
        num_experts_per_tok: rng.range(1, 8).min(8),
        num_hidden_layers: l,
        first_k_dense: rng.below(l.min(4)),
        vocab_size: 1000 * rng.range(2, 150),
        tie_word_embeddings: rng.below(2) == 0,
    }
}

/// Random parallel config valid for `m` (EP | N, EDP integral, plan non-empty).
fn random_parallel(rng: &mut Rng64, m: &ModelConfig) -> ParallelConfig {
    loop {
        let tp = [1u64, 2, 4, 8][rng.below(4) as usize];
        let pp = [1u64, 2, 4, 8, 16][rng.below(5) as usize];
        let dp = [1u64, 2, 4, 8, 16, 32][rng.below(6) as usize];
        let ep_choices: Vec<u64> =
            [1u64, 2, 4, 8, 16].iter().copied().filter(|e| m.n_routed_experts % e == 0).collect();
        let ep = ep_choices[rng.below(ep_choices.len() as u64) as usize];
        let p = ParallelConfig { dp, tp, pp, ep, etp: 1 };
        if p.validate().is_ok()
            && StageSplit::FrontLoaded.layer_counts(m.num_hidden_layers, pp).is_ok()
            && m.attn_inner_dim() % tp == 0
            && m.intermediate_size % tp == 0
            && m.vocab_size % tp == 0
        {
            return p;
        }
    }
}

#[test]
fn stage_plans_partition_layers_and_params() {
    let mut rng = Rng64::new(0xA11CE);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        for split in [StageSplit::FrontLoaded, StageSplit::Balanced] {
            for pp in [1u64, 2, 4, 8] {
                if split.layer_counts(m.num_hidden_layers, pp).is_err() {
                    continue;
                }
                let plan = StagePlan::build(&m, pp, split.clone(), CountMode::Strict);
                let total: u64 = plan.stages.iter().map(|s| s.num_layers).sum();
                assert_eq!(total, m.num_hidden_layers, "case {case}");
                let strict = dsmem::model::ModelParams::build(&m, CountMode::Strict).total();
                assert_eq!(plan.total_params(), strict, "case {case}");
            }
        }
    }
}

#[test]
fn zero_strategies_never_increase_memory() {
    let mut rng = Rng64::new(0xBEEF);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let p = random_parallel(&mut rng, &m);
        let mm = MemoryModel::new(&m, &p, DtypePolicy::paper_bf16());
        let zr = mm.zero_report();
        let totals: Vec<u64> = ZeroStrategy::ALL.iter().map(|&z| zr.row(z).total_bytes()).collect();
        for w in totals.windows(2) {
            assert!(w[0] >= w[1], "case {case}: {totals:?}");
        }
        // Sharded params never exceed unsharded.
        assert!(zr.sharded_params <= zr.device_params, "case {case}");
    }
}

#[test]
fn device_partition_bounded_by_stage_total() {
    // One device never stores more than the whole stage (strict counting),
    // and TP/EP degrees only shrink its share.
    let mut rng = Rng64::new(0xCAFE);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let p = random_parallel(&mut rng, &m);
        let mm = MemoryModel::new(&m, &p, DtypePolicy::paper_bf16()).with_mode(CountMode::Strict);
        let plan = mm.stage_plan();
        let dev = mm.device_static_params();
        let stage_total = plan.stages[plan.heaviest_stage()].params
            + dsmem::model::dense::final_norm_params(&m); // last stage may add it
        assert!(
            dev.total_params() <= stage_total + m.hidden_size,
            "case {case}: dev {} > stage {stage_total}",
            dev.total_params()
        );
    }
}

#[test]
fn activation_tapes_scale_linearly_and_order_correctly() {
    let mut rng = Rng64::new(0xD00D);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let p = random_parallel(&mut rng, &m);
        let s = 128 * rng.range(1, 8) * p.tp; // keep divisible by sp
        let mk = |b: u64| ActivationConfig {
            micro_batch: b,
            seq_len: s,
            sp: p.tp,
            cp: 1,
            recompute: RecomputePolicy::None,
        };
        let mm = MemoryModel::new(&m, &p, DtypePolicy::paper_bf16());
        let r1 = mm.activation_report(&mk(1));
        let r3 = mm.activation_report(&mk(3));
        assert_eq!(
            3 * r1.total_stage_bytes(RecomputePolicy::None),
            r3.total_stage_bytes(RecomputePolicy::None),
            "case {case}: not linear in b"
        );
        let none = r1.total_stage_bytes(RecomputePolicy::None);
        let full = r1.total_stage_bytes(RecomputePolicy::Full);
        assert!(full < none, "case {case}");
    }
}

#[test]
fn rank_grid_groups_always_partition() {
    let mut rng = Rng64::new(0x51DE);
    for case in 0..50 {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let p = random_parallel(&mut rng, &m);
        let grid = RankGrid::new(p).unwrap();
        for kind in [GroupKind::Dp, GroupKind::Tp, GroupKind::Pp, GroupKind::Ep, GroupKind::Edp] {
            let groups = build_groups(&grid, kind);
            let covered: u64 = groups.iter().map(|g| g.ranks.len() as u64).sum();
            assert_eq!(covered, grid.world_size(), "case {case} {kind:?}");
        }
        // Round-trip every rank.
        for r in 0..grid.world_size() {
            assert_eq!(grid.rank(grid.coord(r)), r);
        }
    }
}

#[test]
fn schedules_preserve_invariants_for_random_shapes() {
    let mut rng = Rng64::new(0x7EA);
    for _ in 0..100 {
        let p = rng.range(1, 24);
        let m = rng.range(p, p + 64); // m >= p keeps 1F1B well-formed
        for kind in [
            dsmem::sim::ScheduleKind::GPipe,
            dsmem::sim::ScheduleKind::OneFOneB,
            dsmem::sim::ScheduleKind::Interleaved1F1B { chunks: rng.range(1, 4) },
        ] {
            let s = dsmem::sim::Schedule::build(kind, p, m).unwrap();
            s.check_invariants().unwrap();
            for stage in 0..p {
                if matches!(kind, dsmem::sim::ScheduleKind::GPipe | dsmem::sim::ScheduleKind::OneFOneB) {
                    assert_eq!(s.peak_inflight(stage), s.analytic_inflight(stage));
                }
            }
        }
    }
}

#[test]
fn byte_model_scales_exactly_with_dtype_width() {
    // The whole analysis is linear in bytes-per-element: fp32 weights must
    // double every bf16 figure.
    let mut rng = Rng64::new(0x900D);
    for _ in 0..50 {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let p = random_parallel(&mut rng, &m);
        let mm16 = MemoryModel::new(&m, &p, DtypePolicy::paper_bf16());
        let mut d32 = DtypePolicy::paper_bf16();
        d32.weight = Dtype::Fp32;
        let mm32 = MemoryModel::new(&m, &p, d32);
        assert_eq!(
            2 * mm16.device_static_params().total_bytes(),
            mm32.device_static_params().total_bytes()
        );
    }
}
