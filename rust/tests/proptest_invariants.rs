//! Property tests (hand-rolled generator sweep — the offline build has no
//! proptest crate): randomized (model, parallel, activation) configurations
//! must uphold the analytical model's invariants, and the planner subsystem
//! must uphold its search invariants (pruning, feasibility, Pareto
//! non-domination, legacy-sweep equivalence).

use dsmem::analysis::total::DeviceMemoryReport;
use dsmem::analysis::{
    ClusterMemoryAtlas, MemoryModel, Overheads, StageInflight, StagePlan, StageSplit, ZeroStrategy,
};
use dsmem::config::{
    ActivationConfig, CaseStudy, Dtype, DtypePolicy, ModelConfig, ParallelConfig, RecomputePolicy,
};
use dsmem::model::CountMode;
use dsmem::parallel::{build_groups, GroupKind, RankGrid};
use dsmem::planner::{
    pareto, plan, plan_offline, plan_with_threads, plan_with_threads_kernel, BlockScratch,
    Evaluator, PlanKernel, PlanQuery, SearchSpace,
};
use dsmem::schedule::{registry, Schedule, ScheduleSpec};
use dsmem::util::Rng64;

const CASES: usize = 200;

/// Random valid model config (DeepSeek-shaped, divisibility respected).
fn random_model(rng: &mut Rng64) -> ModelConfig {
    let nh = [4u64, 8, 16, 32, 64, 128][rng.below(6) as usize];
    let l = rng.range(4, 80);
    ModelConfig {
        name: "random".into(),
        hidden_size: 64 * rng.range(2, 120),
        moe_intermediate_size: 64 * rng.range(1, 40),
        intermediate_size: 64 * rng.range(4, 300),
        qk_nope_head_dim: [32u64, 64, 128][rng.below(3) as usize],
        num_attention_heads: nh,
        q_lora_rank: 64 * rng.range(1, 30),
        qk_rope_head_dim: [16u64, 32, 64][rng.below(3) as usize],
        kv_lora_rank: 64 * rng.range(1, 10),
        n_routed_experts: [8u64, 16, 32, 64, 128, 256][rng.below(6) as usize],
        n_shared_experts: rng.range(1, 3),
        num_experts_per_tok: rng.range(1, 8).min(8),
        num_hidden_layers: l,
        first_k_dense: rng.below(l.min(4)),
        vocab_size: 1000 * rng.range(2, 150),
        tie_word_embeddings: rng.below(2) == 0,
    }
}

/// Random parallel config valid for `m` (EP | N, EDP integral, plan non-empty).
fn random_parallel(rng: &mut Rng64, m: &ModelConfig) -> ParallelConfig {
    loop {
        let tp = [1u64, 2, 4, 8][rng.below(4) as usize];
        let pp = [1u64, 2, 4, 8, 16][rng.below(5) as usize];
        let dp = [1u64, 2, 4, 8, 16, 32][rng.below(6) as usize];
        let ep_choices: Vec<u64> =
            [1u64, 2, 4, 8, 16].iter().copied().filter(|e| m.n_routed_experts % e == 0).collect();
        let ep = ep_choices[rng.below(ep_choices.len() as u64) as usize];
        let p = ParallelConfig { dp, tp, pp, ep, etp: 1 };
        if p.validate().is_ok()
            && StageSplit::FrontLoaded.layer_counts(m.num_hidden_layers, pp).is_ok()
            && m.attn_inner_dim() % tp == 0
            && m.intermediate_size % tp == 0
            && m.vocab_size % tp == 0
        {
            return p;
        }
    }
}

#[test]
fn stage_plans_partition_layers_and_params() {
    let mut rng = Rng64::new(0xA11CE);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        for split in [StageSplit::FrontLoaded, StageSplit::Balanced] {
            for pp in [1u64, 2, 4, 8] {
                if split.layer_counts(m.num_hidden_layers, pp).is_err() {
                    continue;
                }
                let plan = StagePlan::build(&m, pp, split.clone(), CountMode::Strict);
                let total: u64 = plan.stages.iter().map(|s| s.num_layers).sum();
                assert_eq!(total, m.num_hidden_layers, "case {case}");
                let strict = dsmem::model::ModelParams::build(&m, CountMode::Strict).total();
                assert_eq!(plan.total_params(), strict, "case {case}");
            }
        }
    }
}

#[test]
fn zero_strategies_never_increase_memory() {
    let mut rng = Rng64::new(0xBEEF);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let p = random_parallel(&mut rng, &m);
        let mm = MemoryModel::new(&m, &p, DtypePolicy::paper_bf16());
        let zr = mm.zero_report();
        let totals: Vec<u64> = ZeroStrategy::ALL.iter().map(|&z| zr.row(z).total_bytes()).collect();
        for w in totals.windows(2) {
            assert!(w[0] >= w[1], "case {case}: {totals:?}");
        }
        // Sharded params never exceed unsharded.
        assert!(zr.sharded_params <= zr.device_params, "case {case}");
    }
}

#[test]
fn device_partition_bounded_by_stage_total() {
    // One device never stores more than the whole stage (strict counting),
    // and TP/EP degrees only shrink its share.
    let mut rng = Rng64::new(0xCAFE);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let p = random_parallel(&mut rng, &m);
        let mm = MemoryModel::new(&m, &p, DtypePolicy::paper_bf16()).with_mode(CountMode::Strict);
        let plan = mm.stage_plan();
        let dev = mm.device_static_params();
        let stage_total = plan.stages[plan.paper_archetype_stage()].params
            + dsmem::model::dense::final_norm_params(&m); // last stage may add it
        assert!(
            dev.total_params() <= stage_total + m.hidden_size,
            "case {case}: dev {} > stage {stage_total}",
            dev.total_params()
        );
    }
}

#[test]
fn activation_tapes_scale_linearly_and_order_correctly() {
    let mut rng = Rng64::new(0xD00D);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let p = random_parallel(&mut rng, &m);
        let s = 128 * rng.range(1, 8) * p.tp; // keep divisible by sp
        let mk = |b: u64| ActivationConfig {
            micro_batch: b,
            seq_len: s,
            sp: p.tp,
            cp: 1,
            recompute: RecomputePolicy::None,
        };
        let mm = MemoryModel::new(&m, &p, DtypePolicy::paper_bf16());
        let r1 = mm.activation_report(&mk(1));
        let r3 = mm.activation_report(&mk(3));
        assert_eq!(
            3 * r1.total_stage_bytes(RecomputePolicy::None),
            r3.total_stage_bytes(RecomputePolicy::None),
            "case {case}: not linear in b"
        );
        let none = r1.total_stage_bytes(RecomputePolicy::None);
        let full = r1.total_stage_bytes(RecomputePolicy::Full);
        assert!(full < none, "case {case}");
    }
}

#[test]
fn rank_grid_groups_always_partition() {
    let mut rng = Rng64::new(0x51DE);
    for case in 0..50 {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let p = random_parallel(&mut rng, &m);
        let grid = RankGrid::new(p).unwrap();
        for kind in [GroupKind::Dp, GroupKind::Tp, GroupKind::Pp, GroupKind::Ep, GroupKind::Edp] {
            let groups = build_groups(&grid, kind);
            let covered: u64 = groups.iter().map(|g| g.ranks.len() as u64).sum();
            assert_eq!(covered, grid.world_size(), "case {case} {kind:?}");
        }
        // Round-trip every rank.
        for r in 0..grid.world_size() {
            assert_eq!(grid.rank(grid.coord(r)), r);
        }
    }
}

#[test]
fn every_registered_schedule_upholds_replay_and_bubble_invariants() {
    // For every registered schedule (plus random interleaved chunk counts)
    // and random (p, m): the replayed peak_inflight equals the schedule's
    // analytic bound on every stage, the op invariants hold, and the bubble
    // fraction is in [0, 1) and non-increasing in m.
    let mut rng = Rng64::new(0x7EA);
    for _ in 0..100 {
        let p = rng.range(1, 24);
        let m = rng.range(p, p + 64); // m >= p keeps 1F1B well-formed
        let mut specs = registry();
        specs.push(ScheduleSpec::Interleaved1F1B { chunks: rng.range(1, 5) });
        for spec in specs {
            let sched = spec.resolve();
            if sched.validate(p, m).is_err() {
                continue; // e.g. DualPipe with odd p/m — covered below
            }
            let s = Schedule::build(spec, p, m).unwrap();
            s.check_invariants().unwrap();
            for stage in 0..p {
                assert_eq!(
                    s.peak_inflight(stage),
                    s.analytic_inflight(stage),
                    "{} p={p} m={m} stage={stage}",
                    spec.name()
                );
            }
            let b = sched.bubble_fraction(p, m);
            assert!((0.0..1.0).contains(&b), "{} p={p} m={m}: bubble {b}", spec.name());
            if sched.validate(p, m + 2).is_ok() {
                assert!(
                    sched.bubble_fraction(p, m + 2) <= b,
                    "{} bubble not monotone in m",
                    spec.name()
                );
            }
        }
    }
    // DualPipe needs even p, even m ≥ 2p — dedicated random coverage so the
    // generic loop's skips don't leave it untested.
    for _ in 0..60 {
        let p = 2 * rng.range(1, 13);
        let m = 2 * p + 2 * rng.range(0, 33);
        let s = Schedule::build(ScheduleSpec::DualPipe, p, m).unwrap();
        s.check_invariants().unwrap();
        for stage in 0..p {
            assert_eq!(
                s.peak_inflight(stage),
                s.analytic_inflight(stage),
                "dualpipe p={p} m={m} stage={stage}"
            );
            assert_eq!(s.peak_inflight(stage), p + 1, "dualpipe holds p+1 uniformly");
        }
    }
}

// ---------------------------------------------------------------------------
// Planner invariants
// ---------------------------------------------------------------------------

/// Random planner search space: a power-of-two world with random non-empty
/// subsets of every axis.
fn random_space(rng: &mut Rng64) -> SearchSpace {
    fn pick(rng: &mut Rng64, options: &[u64]) -> Vec<u64> {
        let keep: Vec<u64> = options.iter().copied().filter(|_| rng.below(2) == 0).collect();
        if keep.is_empty() {
            vec![options[rng.below(options.len() as u64) as usize]]
        } else {
            keep
        }
    }
    let world = [64u64, 128, 256, 512, 1024][rng.below(5) as usize];
    let mut space = SearchSpace::for_world(world);
    space.tp = pick(rng, &[1, 2, 4, 8]);
    space.pp = pick(rng, &[1, 2, 4, 8, 16]);
    space.ep = pick(rng, &[1, 2, 4, 8, 16]);
    space.etp = pick(rng, &[1, 2]);
    space.micro_batch = pick(rng, &[1, 2, 4]);
    space
}

fn planner_model(rng: &mut Rng64) -> ModelConfig {
    if rng.below(2) == 0 {
        ModelConfig::deepseek_v3()
    } else {
        ModelConfig::deepseek_v2()
    }
}

#[test]
fn planner_pruned_grid_is_valid_subset_of_full_grid() {
    let mut rng = Rng64::new(0x9A5);
    for case in 0..12 {
        let m = planner_model(&mut rng);
        let space = random_space(&mut rng);
        let cands = space.enumerate(&m);
        assert!(cands.len() as u64 <= space.full_size(), "case {case}");
        for c in &cands {
            assert!(space.is_valid(&m, &c.parallel, &c.act), "case {case}: {c:?}");
            assert_eq!(c.parallel.world_size(), space.world, "case {case}");
            c.parallel.validate().unwrap();
            c.act.validate().unwrap();
            assert_eq!(m.n_routed_experts % c.parallel.ep, 0, "case {case}");
            StageSplit::FrontLoaded
                .layer_counts(m.num_hidden_layers, c.parallel.pp)
                .unwrap();
        }
    }
}

#[test]
fn planner_frontier_is_feasible_and_mutually_nondominated() {
    let cs = CaseStudy::paper();
    let mut rng = Rng64::new(0xF407);
    for case in 0..8 {
        let m = planner_model(&mut rng);
        let hbm = [40u64, 80, 160][rng.below(3) as usize] * dsmem::GIB as u64;
        let mut query = PlanQuery::new(random_space(&mut rng), hbm);
        query.keep_evaluated = true;
        let res = plan(&m, cs.dtypes, &query);
        assert_eq!(
            res.feasible_count,
            res.evaluated.iter().filter(|p| p.fits(hbm)).count(),
            "case {case}"
        );
        assert!(res.ranked.len() <= query.top_k, "case {case}");
        for p in &res.frontier {
            assert!(p.fits(hbm), "case {case}: infeasible frontier point");
        }
        for a in &res.frontier {
            for b in &res.frontier {
                assert!(!pareto::dominates(a, b), "case {case}: dominated frontier point");
            }
        }
        // Completeness: every feasible point is on the frontier (same
        // objective triple) or strictly dominated by a frontier point.
        for p in res.evaluated.iter().filter(|p| p.fits(hbm)) {
            let covered = res.frontier.iter().any(|f| {
                pareto::dominates(f, p)
                    || (f.total_bytes() == p.total_bytes()
                        && f.bubble == p.bubble
                        && f.device_params == p.device_params)
            });
            assert!(covered, "case {case}: feasible point escapes the frontier");
        }
    }
}

#[test]
fn planner_streaming_fold_matches_offline_pipeline() {
    // The streaming FrontierFold must be bit-identical to the offline
    // feasible → frontier → rank pipeline across random spaces, budgets,
    // top-k values and worker counts (merge order-independence: each thread
    // count induces a different region sharding).
    let cs = CaseStudy::paper();
    let mut rng = Rng64::new(0x57F01D);
    for case in 0..6 {
        let m = planner_model(&mut rng);
        let hbm = [40u64, 80, 160][rng.below(3) as usize] * dsmem::GIB as u64;
        let mut query = PlanQuery::new(random_space(&mut rng), hbm);
        query.top_k = [0usize, 1, 5, 10, 1000][rng.below(5) as usize];
        query.keep_evaluated = true;
        let offline = plan_offline(&m, cs.dtypes, &query);
        for threads in [1usize, 2, 3, 8] {
            let streaming = plan_with_threads(&m, cs.dtypes, &query, threads);
            let tag = format!("case {case} threads {threads} k {}", query.top_k);
            assert_eq!(streaming.evaluated, offline.evaluated, "{tag}");
            assert_eq!(streaming.feasible_count, offline.feasible_count, "{tag}");
            assert_eq!(streaming.counters.evaluated, offline.counters.evaluated, "{tag}");
            assert_eq!(
                streaming.counters.by_binding_stage, offline.counters.by_binding_stage,
                "{tag}"
            );
            assert_eq!(streaming.frontier, offline.frontier, "{tag}");
            assert_eq!(streaming.ranked, offline.ranked, "{tag}");
            // The acceptance criterion verbatim: the rendered snapshot is
            // byte-identical to the pre-change pipeline's.
            assert_eq!(
                dsmem::planner::report::to_json(&streaming).dump(),
                dsmem::planner::report::to_json(&offline).dump(),
                "{tag}"
            );
        }
    }
}

#[test]
fn pruning_never_drops_feasible_points() {
    // The bound-and-prune acceptance bar: (a) the admissibility oracle —
    // every candidate's lower bound is ≤ its exact total, and the layout
    // floor is ≤ the candidate bound, so a pruned candidate's exact total
    // provably exceeds the budget; (b) the oracle's per-candidate bound
    // count equals `counters.pruned` of both paths; (c) the pruning
    // streaming path stays byte-identical to `plan_offline` across random
    // spaces, budget edges (0 and `u64::MAX` included), thread counts and
    // both keep modes.
    let cs = CaseStudy::paper();
    let mut rng = Rng64::new(0xB0B0);
    for case in 0..3 {
        let m = planner_model(&mut rng);
        let space = random_space(&mut rng);
        for hbm in [0u64, 24 * dsmem::GIB as u64, 80 * dsmem::GIB as u64, u64::MAX] {
            let mut query = PlanQuery::new(space.clone(), hbm);
            query.top_k = [0usize, 5][rng.below(2) as usize];
            query.keep_evaluated = true;
            let offline = plan_offline(&m, cs.dtypes, &query);
            // Admissibility oracle: walk the filtered grid in enumeration
            // order, pairing each candidate with its exact evaluated point.
            let ev = Evaluator::new(
                &m,
                cs.dtypes,
                query.mode,
                query.space.split.clone(),
                query.overheads,
                query.num_microbatches,
            );
            let mut i = 0usize;
            let mut by_bound = 0u64;
            for c in query.space.candidates(&m) {
                if c.schedule.resolve().validate(c.parallel.pp, query.num_microbatches).is_err()
                {
                    continue;
                }
                let exact = offline.evaluated[i].total_bytes();
                let lb = ev.lower_bound(&c);
                assert!(lb <= exact, "case {case} hbm {hbm}: {lb} > exact {exact} for {c:?}");
                assert!(
                    ev.layout_floor(&c.parallel) <= lb,
                    "case {case} hbm {hbm}: layout floor above candidate bound for {c:?}"
                );
                if lb > hbm {
                    by_bound += 1;
                    // The one property pruning rests on: bound-pruned ⇒
                    // exactly infeasible. A feasible candidate can never
                    // be pruned.
                    assert!(exact > hbm, "case {case}: pruned a feasible candidate {c:?}");
                }
                i += 1;
            }
            assert_eq!(i as u64, offline.counters.evaluated, "case {case} hbm {hbm}");
            assert_eq!(by_bound, offline.counters.pruned, "case {case} hbm {hbm}");
            if hbm == u64::MAX {
                assert_eq!(offline.counters.pruned, 0, "case {case}: nothing exceeds MAX");
            }
            if hbm == 0 {
                assert_eq!(offline.feasible_count, 0, "case {case}");
                assert_eq!(
                    offline.counters.pruned, offline.counters.evaluated,
                    "case {case}: everything exceeds a zero budget"
                );
            }
            // Byte-identity of the pruning path against the no-skip oracle,
            // with the subtree skips actually armed (keep_evaluated=false)
            // and disarmed.
            for threads in [1usize, 3] {
                for keep in [false, true] {
                    let mut q = query.clone();
                    q.keep_evaluated = keep;
                    let streaming = plan_with_threads(&m, cs.dtypes, &q, threads);
                    let tag = format!("case {case} hbm {hbm} threads {threads} keep {keep}");
                    assert_eq!(streaming.counters, offline.counters, "{tag}");
                    assert_eq!(streaming.feasible_count, offline.feasible_count, "{tag}");
                    assert_eq!(streaming.frontier, offline.frontier, "{tag}");
                    assert_eq!(streaming.ranked, offline.ranked, "{tag}");
                    if keep {
                        assert_eq!(streaming.evaluated, offline.evaluated, "{tag}");
                    }
                    assert_eq!(
                        dsmem::planner::report::to_json(&streaming).dump(),
                        dsmem::planner::report::to_json(&offline).dump(),
                        "{tag}"
                    );
                }
            }
        }
    }
}

#[test]
fn block_eval_matches_candidate_eval() {
    // The block kernel's acceptance bar: (a) per candidate, the block
    // fan-out (begin_block + block_point over the trailing schedule × ZeRO
    // axes) is bit-identical to the scalar `evaluate` path — binding stage,
    // full ledger, every counter input; (b) end to end, a plan run on the
    // block kernel is byte-identical to the scalar kernel AND the offline
    // oracle across random spaces, budget edges (0 and `u64::MAX` — pruning
    // fully armed and fully disarmed), thread counts and both keep modes.
    let cs = CaseStudy::paper();
    let mut rng = Rng64::new(0xB10C);
    for case in 0..3 {
        let m = planner_model(&mut rng);
        let space = random_space(&mut rng);

        // (a) Per-candidate bit-identity on a prefix of the filtered grid.
        let ev = Evaluator::new(
            &m,
            cs.dtypes,
            CountMode::PaperCompat,
            StageSplit::FrontLoaded,
            Overheads::paper_midpoint(),
            32,
        );
        let mut scratch = BlockScratch::default();
        let mut it = space.candidates(&m);
        let mut bases = 0usize;
        while let Some((parallel, act)) = it.next_base() {
            if bases >= 24 {
                break;
            }
            bases += 1;
            let block =
                ev.evaluate_block(&parallel, &act, &space.zero, &space.schedule, &mut scratch);
            let scalar: Vec<_> = space
                .zero
                .iter()
                .flat_map(|&zero| {
                    space.schedule.iter().filter_map(move |&schedule| {
                        schedule
                            .resolve()
                            .validate(parallel.pp, 32)
                            .ok()
                            .map(|_| dsmem::planner::Candidate { parallel, act, zero, schedule })
                    })
                })
                .map(|c| ev.evaluate(&c))
                .collect();
            assert_eq!(block, scalar, "case {case}: block fan-out diverges at base {bases}");
        }

        // (b) End-to-end byte-identity of the block-kernel plan runs.
        for hbm in [0u64, 24 * dsmem::GIB as u64, 80 * dsmem::GIB as u64, u64::MAX] {
            let mut query = PlanQuery::new(space.clone(), hbm);
            query.top_k = [0usize, 5][rng.below(2) as usize];
            query.keep_evaluated = true;
            let offline = plan_offline(&m, cs.dtypes, &query);
            for threads in [1usize, 3] {
                for keep in [false, true] {
                    let mut q = query.clone();
                    q.keep_evaluated = keep;
                    let block =
                        plan_with_threads_kernel(&m, cs.dtypes, &q, threads, PlanKernel::Block);
                    let scalar =
                        plan_with_threads_kernel(&m, cs.dtypes, &q, threads, PlanKernel::Scalar);
                    let tag = format!("case {case} hbm {hbm} threads {threads} keep {keep}");
                    assert_eq!(block.counters, scalar.counters, "{tag}");
                    assert_eq!(block.counters, offline.counters, "{tag}");
                    assert_eq!(block.feasible_count, offline.feasible_count, "{tag}");
                    assert_eq!(block.frontier, offline.frontier, "{tag}");
                    assert_eq!(block.ranked, offline.ranked, "{tag}");
                    if keep {
                        assert_eq!(block.evaluated, offline.evaluated, "{tag}");
                        assert_eq!(block.evaluated, scalar.evaluated, "{tag}");
                    }
                    assert_eq!(
                        dsmem::planner::report::to_json(&block).dump(),
                        dsmem::planner::report::to_json(&scalar).dump(),
                        "{tag}"
                    );
                    assert_eq!(
                        dsmem::planner::report::to_json(&block).dump(),
                        dsmem::planner::report::to_json(&offline).dump(),
                        "{tag}"
                    );
                }
            }
        }
    }
}

#[test]
fn planner_shim_matches_legacy_sweep_bit_identically() {
    // The acceptance bar for the sweep → planner migration: the shim must
    // reproduce the historical hand-rolled loop (re-created here verbatim)
    // point for point, byte for byte, in the historical iteration order.
    let cs = CaseStudy::paper();
    let mm = MemoryModel::new(&cs.model, &cs.parallel, cs.dtypes);
    for ov in [Overheads::none(), Overheads::paper_midpoint()] {
        let hbm80 = 80 * dsmem::GIB as u64;
        let mut legacy = Vec::new();
        for b in [1u64, 2, 4] {
            for rc in [
                RecomputePolicy::None,
                RecomputePolicy::SelectiveAttention,
                RecomputePolicy::Full,
            ] {
                for z in ZeroStrategy::ALL {
                    let act = ActivationConfig { micro_batch: b, recompute: rc, ..cs.activation };
                    let rep = DeviceMemoryReport::build(&mm, &act, z, ov);
                    legacy.push((b, rc, z, rep.total_bytes(), rep.fits(hbm80)));
                }
            }
        }
        let shim = dsmem::analysis::total::sweep(&mm, &cs.activation, ov);
        assert_eq!(shim.len(), legacy.len());
        for (s, (b, rc, z, total, fits)) in shim.iter().zip(&legacy) {
            assert_eq!(s.micro_batch, *b);
            assert_eq!(s.recompute, *rc);
            assert_eq!(s.zero, *z);
            assert_eq!(s.total_bytes, *total, "b={b} {rc:?} {z:?}");
            assert_eq!(s.fits_80g, *fits);
        }
    }
}

#[test]
fn ledger_totals_match_flat_arithmetic_for_random_configs() {
    // The ledger refactor's acceptance bar, randomized: a report's grand
    // total must equal the pre-refactor flat arithmetic (ZeroRow + stage
    // activations + comm buffers + fragmentation-of-allocated) bit for bit,
    // and the component groups must re-sum to their flat counterparts.
    let mut rng = Rng64::new(0x1ED6E2);
    let ov = Overheads::paper_midpoint();
    for case in 0..60 {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let p = random_parallel(&mut rng, &m);
        let mm = MemoryModel::new(&m, &p, DtypePolicy::paper_bf16());
        let act = ActivationConfig {
            micro_batch: rng.range(1, 4),
            seq_len: 128 * rng.range(1, 8) * p.tp,
            sp: p.tp,
            cp: 1,
            recompute: RecomputePolicy::None,
        };
        for z in ZeroStrategy::ALL {
            let rep = DeviceMemoryReport::build(&mm, &act, z, ov);
            let zr = mm.zero_report();
            let row = zr.row(z);
            let ar = mm.activation_report(&act);
            let allocated = row.total_bytes() + ar.total_stage_bytes(act.recompute);
            let expected =
                allocated + ov.comm_buffer_bytes + ov.fragmentation_bytes(allocated);
            assert_eq!(rep.total_bytes(), expected, "case {case} {z:?}");
            assert_eq!(rep.params_bytes(), row.params_bytes, "case {case} {z:?}");
            assert_eq!(
                rep.activation_bytes(),
                ar.total_stage_bytes(act.recompute),
                "case {case} {z:?}"
            );
        }
    }
}

#[test]
fn planner_contains_paper_point_with_schedule_scaled_total() {
    // The paper's exact configuration must appear in a default world-1024
    // grid under every registered schedule. Static classes must match the
    // direct facade report; activations must be the facade's per-microbatch
    // figure scaled by the schedule's analytic in-flight count at the
    // analysed stage (1F1B at stage 1 of p=16 with m=32: 15 tapes).
    let cs = CaseStudy::paper();
    let mut q = PlanQuery::new(SearchSpace::for_world(1024), 80 * dsmem::GIB as u64);
    q.keep_evaluated = true;
    let res = plan(&cs.model, cs.dtypes, &q);
    let mm = MemoryModel::new(&cs.model, &cs.parallel, cs.dtypes);
    let direct = DeviceMemoryReport::build(
        &mm,
        &cs.activation,
        ZeroStrategy::OsG,
        Overheads::paper_midpoint(),
    );
    let archetype = mm.stage_plan().paper_archetype_stage() as u64;
    for spec in registry() {
        let sched = spec.resolve();
        if sched.validate(cs.parallel.pp, q.num_microbatches).is_err() {
            continue;
        }
        let found = res
            .evaluated
            .iter()
            .find(|p| {
                p.parallel == cs.parallel
                    && p.micro_batch == 1
                    && p.sp == 2
                    && p.recompute == RecomputePolicy::None
                    && p.zero == ZeroStrategy::OsG
                    && p.schedule == spec
            })
            .unwrap_or_else(|| panic!("paper configuration missing for {}", spec.name()));
        // For the paper's front-loaded PP16 plan the binding stage IS the
        // archetype under every registered schedule (stage 1 carries both
        // the heaviest params and the biggest tape), so the legacy scaling
        // law still pins the point's ledger exactly.
        assert_eq!(found.binding_stage, archetype, "{}", spec.name());
        let inflight =
            sched.analytic_inflight(archetype, cs.parallel.pp, q.num_microbatches);
        let units = sched.units_per_microbatch().max(1);
        assert_eq!(
            found.params_bytes(),
            sched.param_multiplier() * direct.params_bytes(),
            "{}",
            spec.name()
        );
        assert_eq!(found.gradient_bytes(), direct.gradient_bytes());
        assert_eq!(found.optimizer_bytes(), direct.optimizer_bytes());
        // Activation scaling is component-wise (each component's tape divided
        // into schedule units, times the in-flight count) — the same
        // arithmetic the sim engine replays.
        for c in dsmem::ledger::Component::ALL {
            if c.group() == dsmem::ledger::ComponentGroup::Activation {
                assert_eq!(
                    found.ledger.get(c),
                    (direct.ledger.get(c) / units) * inflight,
                    "{} {}",
                    spec.name(),
                    c.name()
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Cluster-atlas invariants
// ---------------------------------------------------------------------------

/// Random valid per-stage layer counts for `(l, pp)`: one layer each, the
/// remainder scattered uniformly.
fn random_custom_split(rng: &mut Rng64, l: u64, pp: u64) -> StageSplit {
    let mut counts = vec![1u64; pp as usize];
    for _ in 0..(l - pp) {
        counts[rng.below(pp) as usize] += 1;
    }
    StageSplit::Custom(counts)
}

#[test]
fn atlas_stage_params_partition_model_total_for_every_split() {
    // The atlas's per-stage census must partition the model exactly under
    // front-loaded, balanced AND arbitrary custom splits: layer counts sum
    // to L, per-stage params sum to the strict model total.
    let mut rng = Rng64::new(0xA71A5);
    for case in 0..CASES {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let strict = dsmem::model::ModelParams::build(&m, CountMode::Strict).total();
        for pp in [1u64, 2, 4, 8] {
            if pp > m.num_hidden_layers {
                continue;
            }
            let mut splits = vec![random_custom_split(&mut rng, m.num_hidden_layers, pp)];
            if StageSplit::FrontLoaded.layer_counts(m.num_hidden_layers, pp).is_ok() {
                splits.push(StageSplit::FrontLoaded);
            }
            splits.push(StageSplit::Balanced);
            for split in splits {
                let plan = StagePlan::build(&m, pp, split, CountMode::Strict);
                let layers: u64 = plan.stages.iter().map(|s| s.num_layers).sum();
                assert_eq!(layers, m.num_hidden_layers, "case {case} pp={pp}");
                assert_eq!(plan.total_params(), strict, "case {case} pp={pp}");
            }
        }
    }
}

#[test]
fn atlas_max_total_dominates_the_legacy_archetype_total() {
    // The issue's headline invariant: the per-stage totals' max is at least
    // the legacy archetype-stage total — feasibility can only get stricter,
    // never looser, when every stage is analysed. On pure-MoE archetype
    // stages (the paper's analysed shape) the archetype entry itself must be
    // bit-identical to the legacy report.
    let mut rng = Rng64::new(0xA71A6);
    let ov = Overheads::paper_midpoint();
    for case in 0..60 {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let p = random_parallel(&mut rng, &m);
        let mm = MemoryModel::new(&m, &p, DtypePolicy::paper_bf16());
        let act = ActivationConfig {
            micro_batch: rng.range(1, 4),
            seq_len: 128 * rng.range(1, 8) * p.tp,
            sp: p.tp,
            cp: 1,
            recompute: RecomputePolicy::None,
        };
        let plan = mm.stage_plan();
        let archetype = plan.paper_archetype_stage();
        let pure_moe =
            plan.stages[archetype].moe_layers == plan.stages[archetype].num_layers;
        if !pure_moe {
            // Dense-bearing archetypes use a different (exact) activation
            // convention than the legacy all-MoE approximation; the
            // domination claim is only meaningful on the paper's shape.
            continue;
        }
        let inflight = StageInflight::per_microbatch(p.pp);
        for z in ZeroStrategy::ALL {
            let atlas = ClusterMemoryAtlas::build(&mm, &act, z, ov, &inflight).unwrap();
            let legacy = DeviceMemoryReport::build(&mm, &act, z, ov);
            assert!(
                atlas.max_total_bytes() >= legacy.total_bytes(),
                "case {case} {z:?}: max {} < legacy {}",
                atlas.max_total_bytes(),
                legacy.total_bytes()
            );
            assert_eq!(atlas.entries[archetype].ledger, legacy.ledger, "case {case} {z:?}");
            let binding = atlas.binding_stage();
            assert!(
                atlas.entries[binding].total_bytes() >= atlas.entries[archetype].total_bytes()
            );
        }
    }
}

#[test]
fn atlas_output_is_byte_stable_across_thread_counts() {
    // The atlas rides through the planner's thread-parallel evaluation and
    // the suite's thread-parallel runner: sequential and parallel paths must
    // produce byte-identical results, and two atlas builds must serialize
    // to identical JSON.
    use dsmem::planner::{Candidate, Evaluator, PlanPoint};
    let cs = CaseStudy::paper();
    let mut space = SearchSpace::for_world(1024);
    space.pp = vec![16];
    space.etp = vec![1];
    let cands: Vec<Candidate> = space
        .candidates(&cs.model)
        .filter(|c| c.schedule.resolve().validate(c.parallel.pp, 32).is_ok())
        .take(200)
        .collect();
    let ev = Evaluator::new(
        &cs.model,
        cs.dtypes,
        CountMode::PaperCompat,
        StageSplit::FrontLoaded,
        Overheads::paper_midpoint(),
        32,
    );
    let seq: Vec<PlanPoint> = cands.iter().map(|c| ev.evaluate(c)).collect();
    let par = ev.evaluate_all(&cands);
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.binding_stage, b.binding_stage);
        assert_eq!(a.ledger, b.ledger);
        assert_eq!(a.device_params, b.device_params);
    }
    let mm = MemoryModel::new(&cs.model, &cs.parallel, cs.dtypes);
    let inflight = StageInflight::for_schedule(ScheduleSpec::OneFOneB, 16, 32).unwrap();
    let j1 = dsmem::scenario::runner::atlas_json(
        &ClusterMemoryAtlas::build(
            &mm,
            &cs.activation,
            ZeroStrategy::OsG,
            Overheads::paper_midpoint(),
            &inflight,
        )
        .unwrap(),
        80 * dsmem::GIB as u64,
    );
    let j2 = dsmem::scenario::runner::atlas_json(
        &ClusterMemoryAtlas::build(
            &mm,
            &cs.activation,
            ZeroStrategy::OsG,
            Overheads::paper_midpoint(),
            &inflight,
        )
        .unwrap(),
        80 * dsmem::GIB as u64,
    );
    assert_eq!(j1.pretty(), j2.pretty());
    assert!(!j1.pretty().is_empty());
}

#[test]
fn byte_model_scales_exactly_with_dtype_width() {
    // The whole analysis is linear in bytes-per-element: fp32 weights must
    // double every bf16 figure.
    let mut rng = Rng64::new(0x900D);
    for _ in 0..50 {
        let m = random_model(&mut rng);
        if m.validate().is_err() {
            continue;
        }
        let p = random_parallel(&mut rng, &m);
        let mm16 = MemoryModel::new(&m, &p, DtypePolicy::paper_bf16());
        let mut d32 = DtypePolicy::paper_bf16();
        d32.weight = Dtype::Fp32;
        let mm32 = MemoryModel::new(&m, &p, d32);
        assert_eq!(
            2 * mm16.device_static_params().total_bytes(),
            mm32.device_static_params().total_bytes()
        );
    }
}
