//! Scenario-suite regression harness: runs every checked-in scenario under
//! `scenarios/` and byte-compares the canonical snapshots against the golden
//! files under `scenarios/golden/`.
//!
//! * `DSMEM_BLESS=1 cargo test -q scenario_suite` regenerates the goldens
//!   after an intended behavior change (same as `dsmem suite run --bless`).
//! * On a checkout with no goldens at all, the harness *bootstraps* them
//!   (writes and reports instead of failing) — the offline dev image cannot
//!   pre-generate snapshots; commit the bootstrapped files to arm the gate.
//!
//! The orchestration-equivalence property tests pin the suite to the
//! underlying entry points: for randomized valid specs, `run_scenario`
//! output must be byte-identical to calling `planner::plan` /
//! `planner::sweep_fixed` / `SimEngine::run` / `analysis::inference`
//! directly — the runner is a pure orchestration layer, never a second
//! code path.

use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use dsmem::analysis::total::Overheads;
use dsmem::analysis::{MemoryModel, ZeroStrategy};
use dsmem::config::{CaseStudy, RecomputePolicy};
use dsmem::planner::{self, PlanQuery, SearchSpace};
use dsmem::scenario::{self, ScenarioSpec, SnapshotStatus};
use dsmem::schedule::ScheduleSpec;
use dsmem::sim::SimEngine;
use dsmem::util::Rng64;

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("scenarios")
}

/// One shared full-suite run: the whole suite (including the 100k-device
/// planner stress case) is expensive in the debug profile, so the golden
/// compare and the determinism test split two runs between them instead of
/// paying for three.
fn first_run() -> &'static [scenario::SuiteOutcome] {
    static FIRST: OnceLock<Vec<scenario::SuiteOutcome>> = OnceLock::new();
    FIRST.get_or_init(|| scenario::run_dir(&scenarios_dir()).expect("suite runs"))
}

#[test]
fn suite_matches_checked_in_goldens() {
    let dir = scenarios_dir();
    let scens = scenario::load_dir(&dir).expect("scenario dir loads");
    assert!(scens.len() >= 10, "ship at least 10 scenarios, found {}", scens.len());
    let outcomes = first_run();
    let golden = dir.join("golden");
    if scenario::bless_requested() || !scenario::has_goldens(&golden) {
        let (written, removed) = scenario::bless(&golden, outcomes).expect("bless writes");
        eprintln!(
            "scenario_suite: blessed {written} snapshots into {} ({removed} stale removed); \
             commit them to pin the suite",
            golden.display()
        );
        return;
    }
    let report = scenario::compare(&golden, outcomes).expect("goldens readable");
    if !report.is_clean() {
        for (name, status) in &report.entries {
            match status {
                SnapshotStatus::Match => {}
                SnapshotStatus::Mismatch { diff } => eprintln!("=== {name}: MISMATCH ===\n{diff}"),
                other => eprintln!("=== {name}: {} ===", other.label()),
            }
        }
        panic!(
            "golden snapshots diverged: {} (DSMEM_BLESS=1 to re-bless after an intended change)",
            report.summary()
        );
    }
}

#[test]
fn two_consecutive_suite_runs_are_byte_identical() {
    let a = first_run();
    let b = scenario::run_dir(&scenarios_dir()).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.snapshot, y.snapshot, "scenario {} is nondeterministic", x.name);
    }
}

#[test]
fn suite_covers_every_action_and_model_preset() {
    let scens = scenario::load_dir(&scenarios_dir()).unwrap();
    for action in ["plan", "sweep", "simulate", "kvcache", "atlas"] {
        assert!(scens.iter().any(|s| s.spec.action.name() == action), "no {action} scenario");
    }
    for model in ["v3", "v2", "v2-lite", "mini"] {
        assert!(scens.iter().any(|s| s.spec.model == model), "no {model} scenario");
    }
}

#[test]
fn runner_equals_direct_sweep_entry_point() {
    let mut rng = Rng64::new(0x5CE4A);
    for _ in 0..12 {
        let model = ["mini", "v2-lite"][rng.below(2) as usize];
        let b = [1u64, 2, 4][rng.below(3) as usize];
        let rc = ["none", "selective", "full"][rng.below(3) as usize];
        let hbm = [8u64, 40, 80][rng.below(3) as usize];
        let ov = ["paper", "none"][rng.below(2) as usize];
        let toml = format!(
            "model = \"{model}\"\naction = \"sweep\"\nhbm_gib = {hbm}\noverheads = \"{ov}\"\n\n\
             [activation]\nmicro_batch = {b}\nrecompute = \"{rc}\"\n"
        );
        let spec = ScenarioSpec::from_toml(&toml, "prop-sweep").unwrap();
        let via_runner = scenario::run_scenario(&spec).unwrap();

        let mut cs = CaseStudy::preset(model).unwrap();
        cs.activation.micro_batch = b;
        cs.activation.recompute = RecomputePolicy::parse(rc).unwrap();
        let ovh = if ov == "paper" { Overheads::paper_midpoint() } else { Overheads::none() };
        let mm = MemoryModel::new(&cs.model, &cs.parallel, cs.dtypes);
        let pts = planner::sweep_fixed(&mm, &cs.activation, ovh);
        let direct = scenario::runner::sweep_json(&pts, (hbm as f64 * dsmem::GIB) as u64);
        assert_eq!(
            via_runner.get("result").unwrap().dump(),
            direct.dump(),
            "runner diverged from sweep_fixed for:\n{toml}"
        );
    }
}

#[test]
fn runner_equals_direct_plan_entry_point() {
    let mut rng = Rng64::new(0x71A9);
    for _ in 0..6 {
        let m = [4u64, 8][rng.below(2) as usize];
        let world = [2u64, 4][rng.below(2) as usize];
        let sched = ["all", "1f1b", "gpipe"][rng.below(3) as usize];
        let top_k = rng.range(1, 6);
        let toml = format!(
            "model = \"mini\"\naction = \"plan\"\nhbm_gib = 16\n\n[plan]\nworld = {world}\n\
             microbatches = {m}\ntop_k = {top_k}\nschedule = \"{sched}\"\n"
        );
        let spec = ScenarioSpec::from_toml(&toml, "prop-plan").unwrap();
        let via_runner = scenario::run_scenario(&spec).unwrap();

        let cs = CaseStudy::preset("mini").unwrap();
        let mut space = SearchSpace::for_world(world);
        space.seq_len = cs.activation.seq_len;
        space.cp = cs.activation.cp;
        if sched != "all" {
            space.schedule = vec![ScheduleSpec::parse(sched).unwrap()];
        }
        let mut query = PlanQuery::new(space, (16.0 * dsmem::GIB) as u64);
        query.top_k = top_k as usize;
        query.num_microbatches = m;
        let res = planner::plan(&cs.model, cs.dtypes, &query);
        let direct = planner::report::to_json(&res);
        assert_eq!(
            via_runner.get("result").unwrap().dump(),
            direct.dump(),
            "runner diverged from planner::plan for:\n{toml}"
        );
    }
}

#[test]
fn runner_equals_direct_sim_entry_point() {
    let mut rng = Rng64::new(0xD00D);
    for _ in 0..8 {
        let scheds = ["gpipe", "1f1b", "zb-h1", "interleaved:3", "dualpipe"];
        let sched = scheds[rng.below(5) as usize];
        // DualPipe on the mini preset (p=2) needs an even m >= 4.
        let m = if sched == "dualpipe" { 4 } else { rng.range(2, 8) };
        let zero = ["none", "os", "os_g", "os_g_params"][rng.below(4) as usize];
        let frag = rng.below(2) == 1;
        let toml = format!(
            "model = \"mini\"\naction = \"simulate\"\n\n[simulate]\nschedule = \"{sched}\"\n\
             microbatches = {m}\nzero = \"{zero}\"\nfrag = {frag}\n"
        );
        let spec = ScenarioSpec::from_toml(&toml, "prop-sim").unwrap();
        let via_runner = scenario::run_scenario(&spec).unwrap();

        let cs = CaseStudy::preset("mini").unwrap();
        let zs = ZeroStrategy::parse(zero).unwrap();
        let mm = MemoryModel::new(&cs.model, &cs.parallel, cs.dtypes);
        let mut eng = SimEngine::new(&mm, cs.activation, zs);
        eng.simulate_allocator = frag;
        let res = eng.run(ScheduleSpec::parse(sched).unwrap(), m).unwrap();
        let direct = scenario::runner::simulate_json(&res, zs);
        assert_eq!(
            via_runner.get("result").unwrap().dump(),
            direct.dump(),
            "runner diverged from SimEngine::run for:\n{toml}"
        );
    }
}

#[test]
fn runner_equals_direct_atlas_entry_point() {
    use dsmem::analysis::{ClusterMemoryAtlas, StageInflight, ZeroStrategy as Zs};
    let mut rng = Rng64::new(0xA71A5);
    for _ in 0..10 {
        let model = ["v3", "v2", "v2-lite", "mini"][rng.below(4) as usize];
        let sched = ["1f1b", "gpipe", "zb-h1", "none"][rng.below(4) as usize];
        let m = rng.range(16, 48);
        let zero = ["none", "os", "os_g", "os_g_params"][rng.below(4) as usize];
        let hbm = [40u64, 80][rng.below(2) as usize];
        let ov = ["paper", "none"][rng.below(2) as usize];
        let toml = format!(
            "model = \"{model}\"\naction = \"atlas\"\nhbm_gib = {hbm}\noverheads = \"{ov}\"\n\n\
             [atlas]\nschedule = \"{sched}\"\nmicrobatches = {m}\nzero = \"{zero}\"\n"
        );
        let spec = ScenarioSpec::from_toml(&toml, "prop-atlas").unwrap();
        let via_runner = scenario::run_scenario(&spec).unwrap();

        let cs = CaseStudy::preset(model).unwrap();
        let mm = MemoryModel::new(&cs.model, &cs.parallel, cs.dtypes);
        let inflight = if sched == "none" {
            StageInflight::per_microbatch(cs.parallel.pp)
        } else {
            StageInflight::for_schedule(
                ScheduleSpec::parse(sched).unwrap(),
                cs.parallel.pp,
                m,
            )
            .unwrap()
        };
        let ovh = if ov == "paper" { Overheads::paper_midpoint() } else { Overheads::none() };
        let atlas = ClusterMemoryAtlas::build(
            &mm,
            &cs.activation,
            Zs::parse(zero).unwrap(),
            ovh,
            &inflight,
        )
        .unwrap();
        let direct = scenario::runner::atlas_json(&atlas, hbm * dsmem::GIB as u64);
        assert_eq!(
            via_runner.get("result").unwrap().dump(),
            direct.dump(),
            "runner diverged from the atlas for:\n{toml}"
        );
        // Envelope carries the budget for atlas scenarios.
        assert_eq!(via_runner.get("hbm_gib").unwrap().as_u64().unwrap(), hbm);
    }
}

#[test]
fn runner_equals_direct_kvcache_analysis() {
    use dsmem::analysis::inference::{kv_cache, CacheKind};
    let mut rng = Rng64::new(0xCAFE);
    for _ in 0..8 {
        let model = ["v3", "v2", "v2-lite", "mini"][rng.below(4) as usize];
        let tokens = 1024 * rng.range(1, 64);
        let groups = [4u64, 8][rng.below(2) as usize];
        let toml = format!(
            "model = \"{model}\"\naction = \"kvcache\"\n\n[kvcache]\ntokens = {tokens}\n\
             gqa_groups = {groups}\n"
        );
        let spec = ScenarioSpec::from_toml(&toml, "prop-kv").unwrap();
        let via_runner = scenario::run_scenario(&spec).unwrap();
        let result = via_runner.get("result").unwrap();

        let cs = CaseStudy::preset(model).unwrap();
        let rows = result.get("rows").unwrap().as_arr().unwrap();
        let kinds = [CacheKind::Mha, CacheKind::Gqa { groups }, CacheKind::Mla];
        for (i, kind) in kinds.into_iter().enumerate() {
            let rep = kv_cache(&cs.model, kind, tokens, cs.dtypes.weight, cs.parallel.tp);
            let bpt = rows[i].get("bytes_per_token").unwrap().as_u64().unwrap();
            assert_eq!(bpt, rep.bytes_per_token, "{model} {i}");
            let dev = rows[i].get("device_bytes").unwrap().as_u64().unwrap();
            assert_eq!(dev, rep.device_bytes, "{model} {i}");
        }
        let ratio = result.get("mla_vs_mha_ratio").unwrap().as_f64().unwrap();
        let expect = dsmem::analysis::inference::mla_vs_mha_ratio(&cs.model);
        assert_eq!(ratio, expect, "{model}");
    }
}
