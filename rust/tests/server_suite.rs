//! Serving-equivalence tests for `dsmem serve`: every served response
//! must be byte-identical to the direct entry-point snapshot, the shared
//! cache tier must actually share (nonzero hits at `GET /stats`),
//! concurrent mixed queries must never interleave or corrupt responses,
//! and protocol errors must come back as readable 4xx JSON.

use dsmem::scenario::{self, ScenarioSpec};
use dsmem::server::{run_suite_via_server, start, ServerClient, ServerConfig, ServerHandle};
use dsmem::util::{Json, Rng64};
use std::path::{Path, PathBuf};

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("scenarios")
}

fn boot(threads: usize) -> ServerHandle {
    start(&ServerConfig { addr: "127.0.0.1:0".into(), threads }).expect("test server boots")
}

fn client_of(handle: &ServerHandle) -> ServerClient {
    ServerClient::connect(&handle.addr().to_string()).expect("test client connects")
}

/// The canonical snapshot bytes the local runner would write for `spec`.
fn direct_snapshot(spec: &ScenarioSpec) -> String {
    format!("{}\n", scenario::run_scenario(spec).expect("direct run succeeds").pretty())
}

/// Every cheap committed plan/atlas/kvcache scenario, served over HTTP,
/// answers with exactly the bytes the in-process runner produces.
#[test]
fn served_scenarios_match_direct_entry_points() {
    let handle = boot(2);
    let mut client = client_of(&handle);
    let mut checked = 0;
    for sc in scenario::load_dir(&scenarios_dir()).expect("suite loads") {
        if !matches!(sc.spec.action.name(), "plan" | "atlas" | "kvcache")
            || sc.file.contains("stress")
        {
            continue;
        }
        let direct = direct_snapshot(&sc.spec);
        let served = client
            .post_scenario(sc.spec.action.name(), &sc.spec.name, &sc.toml)
            .expect("served scenario answers");
        assert_eq!(served, direct, "served {} diverges from the direct snapshot", sc.spec.name);
        checked += 1;
    }
    assert!(checked >= 6, "expected at least 6 cheap scenarios to compare, got {checked}");
    drop(client);
    handle.shutdown();
}

/// Repeating an identical query serves identical bytes and leaves
/// nonzero shared-cache hits visible at `GET /stats`.
#[test]
fn repeated_queries_report_shared_cache_hits() {
    let handle = boot(2);
    let mut client = client_of(&handle);
    let toml = "model = \"v3\"\naction = \"plan\"\nhbm_gib = 80\n\n\
                [plan]\nworld = 1024\nmicrobatches = 32\npp = [16]\n";
    let first = client.post_scenario("plan", "hot", toml).expect("first query answers");
    let second = client.post_scenario("plan", "hot", toml).expect("second query answers");
    assert_eq!(first, second, "a repeated identical query must serve identical bytes");
    let (status, body) = client.request("GET", "/stats", "").expect("stats answers");
    assert_eq!(status, 200);
    let stats = Json::parse(&body).expect("stats is JSON");
    let hit_rate = stats.get("hit_rate").and_then(|v| v.as_f64()).expect("aggregate hit_rate");
    assert!(hit_rate > 0.0, "identical repeated queries must hit the shared tier: {body}");
    let plan_hits = stats
        .get("caches")
        .and_then(|c| c.get("stage_plans"))
        .and_then(|c| c.get("hits"))
        .and_then(|v| v.as_f64())
        .expect("stage_plans hits");
    assert!(plan_hits > 0.0, "the stage-plan cache must be shared across queries: {body}");
    drop(client);
    handle.shutdown();
}

/// Four workers hammering three distinct queries concurrently: every
/// response must be exactly the right one — no interleaving, no
/// cross-talk between connections, no tier-warmth dependence.
#[test]
fn concurrent_mixed_queries_never_interleave() {
    let toml_of = |hbm: u64| {
        format!(
            "model = \"v3\"\naction = \"plan\"\nhbm_gib = {hbm}\n\n\
             [plan]\nworld = 1024\nmicrobatches = 32\npp = [16]\n"
        )
    };
    let cases: Vec<(String, String, String)> = [64u64, 80, 96]
        .iter()
        .map(|&hbm| {
            let name = format!("mix-{hbm}");
            let toml = toml_of(hbm);
            let spec = ScenarioSpec::from_toml(&toml, &name).expect("case parses");
            let expected = direct_snapshot(&spec);
            (name, toml, expected)
        })
        .collect();
    let handle = boot(4);
    let addr = handle.addr().to_string();
    std::thread::scope(|s| {
        for worker in 0..4usize {
            let cases = &cases;
            let addr = &addr;
            s.spawn(move || {
                let mut client = ServerClient::connect(addr).expect("worker connects");
                for i in 0..6usize {
                    let (name, toml, expected) = &cases[(worker + i) % cases.len()];
                    let served =
                        client.post_scenario("plan", name, toml).expect("mixed query answers");
                    assert_eq!(
                        &served, expected,
                        "worker {worker} iteration {i}: response for {name} was corrupted"
                    );
                }
            });
        }
    });
    handle.shutdown();
}

/// Generated near-neighbor plan queries (random budget / top-k /
/// microbatches / schedule over one context) serve byte-identically to
/// the direct entry point — including against a warm tier, since cases
/// share the daemon.
#[test]
fn proptest_generated_plans_serve_byte_identically() {
    let handle = boot(2);
    let mut client = client_of(&handle);
    let mut rng = Rng64::new(0xd5ee_5e61);
    for case in 0..6 {
        let hbm = rng.range(40, 121);
        let top_k = rng.below(13);
        let m = [32u64, 64][rng.below(2) as usize];
        let schedule = ["", "schedule = \"1f1b\"\n", "schedule = \"zb-h1\"\n"]
            [rng.below(3) as usize];
        let toml = format!(
            "model = \"v3\"\naction = \"plan\"\nhbm_gib = {hbm}\n\n\
             [plan]\nworld = 1024\nmicrobatches = {m}\npp = [16]\ntop_k = {top_k}\n{schedule}"
        );
        let name = format!("prop-{case}");
        let spec = ScenarioSpec::from_toml(&toml, &name).expect("generated scenario parses");
        let direct = direct_snapshot(&spec);
        let served = client.post_scenario("plan", &name, &toml).expect("generated query answers");
        assert_eq!(served, direct, "case {case} ({toml:?}) diverges when served");
    }
    drop(client);
    handle.shutdown();
}

/// Decode the uniform error body and assert its exact shape:
/// `{"error": {"code": <status>, "endpoint": <path>, "message": ...}}`.
/// Returns the message so callers can assert on its content too.
fn assert_error_shape(body: &str, status: u16, endpoint: &str) -> String {
    let doc = Json::parse(body).unwrap_or_else(|e| panic!("error body is not JSON ({e}): {body}"));
    let err = doc.get("error").expect("body has an 'error' object");
    let code = err.get("code").and_then(|v| v.as_u64()).expect("error.code is a number");
    assert_eq!(code, status as u64, "error.code mirrors the status line: {body}");
    let ep = err.get("endpoint").and_then(|v| v.as_str()).expect("error.endpoint is a string");
    assert_eq!(ep, endpoint, "error.endpoint names the request path: {body}");
    err.get("message")
        .and_then(|v| v.as_str())
        .expect("error.message is a string")
        .to_string()
}

/// Malformed input comes back as readable JSON errors with the right
/// status codes and the one uniform `{"error": {...}}` body shape on
/// every error path, and never kills the daemon.
#[test]
fn protocol_errors_are_readable() {
    let handle = boot(2);
    let mut client = client_of(&handle);
    let (status, body) = client.request("POST", "/plan", "{not json").expect("answers");
    assert_eq!(status, 400, "unparseable JSON body: {body}");
    assert_error_shape(&body, 400, "/plan");
    let (status, body) = client.request("POST", "/plan", "{}").expect("answers");
    assert_eq!(status, 400);
    let msg = assert_error_shape(&body, 400, "/plan");
    assert!(msg.contains("scenario"), "missing-key error names the key: {msg}");
    let plan_toml = "model = \"v3\"\naction = \"plan\"\nhbm_gib = 80\n\n\
                     [plan]\nworld = 1024\nmicrobatches = 32\npp = [16]\n";
    let mut m = std::collections::BTreeMap::new();
    m.insert("scenario".to_string(), Json::Str(plan_toml.into()));
    let (status, body) =
        client.request("POST", "/sweep", &Json::Obj(m).dump()).expect("answers");
    assert_eq!(status, 400, "action/endpoint mismatch must be rejected");
    let msg = assert_error_shape(&body, 400, "/sweep");
    assert!(msg.contains("/plan"), "mismatch error points at the right endpoint: {msg}");
    let (status, body) = client.request("GET", "/plan", "").expect("answers");
    assert_eq!(status, 405, "GET on a POST endpoint");
    assert_error_shape(&body, 405, "/plan");
    let (status, body) = client.request("POST", "/nope", "{}").expect("answers");
    assert_eq!(status, 404);
    let msg = assert_error_shape(&body, 404, "/nope");
    assert!(msg.contains("/healthz"), "404 lists the live endpoints: {msg}");
    assert!(msg.contains("/query"), "404 lists the query endpoint: {msg}");
    let (status, body) = client.request("GET", "/healthz", "").expect("answers");
    assert_eq!(status, 200);
    assert!(body.contains("true"), "healthz acks: {body}");
    drop(client);
    handle.shutdown();
}

/// The full committed suite, driven through a daemon as concurrent HTTP
/// requests, byte-matches every golden snapshot — the same gate CI's
/// serve-smoke job runs via the CLI.
#[test]
fn suite_via_server_matches_goldens() {
    let handle = boot(4);
    let dir = scenarios_dir();
    let report = run_suite_via_server(&dir, &dir.join("golden"), &handle.addr().to_string(), 4)
        .expect("served suite runs");
    assert!(report.is_clean(), "served suite must match goldens: {}", report.summary());
    handle.shutdown();
}

/// Read `coalescing.<field>` out of a fresh `GET /stats` snapshot.
fn coalescing_stat(handle: &ServerHandle, field: &str) -> f64 {
    let mut client = client_of(handle);
    let (status, body) = client.request("GET", "/stats", "").expect("stats answers");
    assert_eq!(status, 200);
    let doc = Json::parse(&body).expect("stats is JSON");
    doc.opt("coalescing")
        .and_then(|c| c.opt(field))
        .and_then(|v| v.as_f64().ok())
        .unwrap_or_else(|| panic!("stats.coalescing.{field} missing: {body}"))
}

/// Identical concurrent POSTs single-flight: one evaluation leads, the
/// duplicates ride along and every response is byte-identical. The burst
/// retries with a fresh flight key if the duplicates happened to land
/// sequentially (single-flight has no memory, so a landed flight cannot
/// coalesce late arrivals — that is the point).
#[test]
fn identical_concurrent_queries_coalesce() {
    let handle = boot(4);
    let addr = handle.addr().to_string();
    // The full default world-1024 space: slow enough (even against warm
    // memo tiers) that 4 simultaneous duplicates overlap the evaluation.
    let toml = "model = \"v3\"\naction = \"plan\"\nhbm_gib = 80\n\n\
                [plan]\nworld = 1024\nmicrobatches = 32\n";
    const N: usize = 4;
    let mut coalesced = 0.0;
    for attempt in 0..5 {
        let name = format!("dup-{attempt}");
        let answers: Vec<String> = std::thread::scope(|s| {
            let workers: Vec<_> = (0..N)
                .map(|_| {
                    let (addr, name) = (&addr, &name);
                    s.spawn(move || {
                        let mut client = ServerClient::connect(addr).expect("dup worker connects");
                        client.post_scenario("plan", name, toml).expect("dup query answers")
                    })
                })
                .collect();
            workers.into_iter().map(|w| w.join().expect("dup worker")).collect()
        });
        for a in &answers[1..] {
            assert_eq!(a, &answers[0], "coalesced duplicates must serve identical bytes");
        }
        coalesced = coalescing_stat(&handle, "coalesced");
        if coalesced > 0.0 {
            break;
        }
    }
    assert!(coalesced > 0.0, "identical concurrent queries never coalesced");
    assert!(coalescing_stat(&handle, "leaders") > 0.0, "every flight needs a leader");
    assert_eq!(coalescing_stat(&handle, "inflight"), 0.0, "all flights must have landed");
    handle.shutdown();
}

/// Distinct concurrent bodies never share a flight: every request leads
/// its own evaluation and the coalesced counter stays at zero.
#[test]
fn distinct_concurrent_queries_never_coalesce() {
    let handle = boot(4);
    let addr = handle.addr().to_string();
    std::thread::scope(|s| {
        for (i, hbm) in [64u64, 80, 96, 112].into_iter().enumerate() {
            let addr = &addr;
            s.spawn(move || {
                let toml = format!(
                    "model = \"v3\"\naction = \"plan\"\nhbm_gib = {hbm}\n\n\
                     [plan]\nworld = 1024\nmicrobatches = 32\npp = [16]\n"
                );
                let name = format!("uniq-{i}");
                let mut client = ServerClient::connect(addr).expect("uniq worker connects");
                client.post_scenario("plan", &name, &toml).expect("distinct query answers");
            });
        }
    });
    assert_eq!(
        coalescing_stat(&handle, "coalesced"),
        0.0,
        "distinct bodies must never share a flight"
    );
    assert_eq!(coalescing_stat(&handle, "leaders"), 4.0, "each distinct body leads once");
    handle.shutdown();
}

/// `POST /shutdown` acks and then drains the whole worker pool — `join`
/// returning is the proof of a clean shutdown.
#[test]
fn shutdown_endpoint_drains_the_pool() {
    let handle = boot(3);
    let mut client = client_of(&handle);
    let (status, body) = client.request("POST", "/shutdown", "").expect("shutdown acks");
    assert_eq!(status, 200);
    assert!(body.contains("shutting_down"), "shutdown ack names itself: {body}");
    drop(client);
    handle.join();
}
