//! Trace-store integration tests.
//!
//! * **Reconciliation**: for every registered schedule, per-stage
//!   `max(total)` / `max(<component>)` read back out of the store must
//!   equal the tracker's [`MemoryTimeline`] peaks exactly — the store is
//!   a second bookkeeping path over the same event stream, so any
//!   divergence is a bug in one of them.
//! * **Steady state**: with the LAG window re-anchored past step 0, every
//!   cross-step delta is exactly zero — replayed steps are identical, so
//!   the growth detector can only ever flag warm-up divergence.
//! * **Byte-identity**: the `dsmem query --json` CLI, the scenario
//!   runner and `POST /query` produce the same snapshot bytes for the
//!   paper's DualPipe PP16 sim.

use dsmem::analysis::{MemoryModel, ZeroStrategy};
use dsmem::config::CaseStudy;
use dsmem::ledger::Component;
use dsmem::scenario::{self, ScenarioSpec};
use dsmem::schedule::{registry, ScheduleSpec};
use dsmem::server::{start, ServerClient, ServerConfig};
use dsmem::sim::{SimEngine, SimResult};
use dsmem::trace_store::{growth_sql, run_query, Value};
use dsmem::util::Rng64;

fn traced_run(model: &str, spec: ScheduleSpec, m: u64, zero: &str, steps: u64) -> SimResult {
    let cs = CaseStudy::preset(model).unwrap();
    let mm = MemoryModel::new(&cs.model, &cs.parallel, cs.dtypes);
    let mut eng = SimEngine::new(&mm, cs.activation, ZeroStrategy::parse(zero).unwrap());
    eng.record_trace = true;
    eng.trace_steps = steps;
    eng.run(spec, m).unwrap()
}

/// `SELECT max(...)` per stage reconciles with the tracker's peaks: the
/// total and all 13 per-component running columns, for every registered
/// schedule, under randomized microbatch counts and ZeRO strategies.
#[test]
fn store_aggregates_reconcile_with_tracker_for_every_schedule() {
    let mut rng = Rng64::new(0x7247_CE01);
    let comps: Vec<String> =
        Component::ALL.iter().map(|c| format!("max({0}) AS peak_{0}", c.name())).collect();
    let sql = format!(
        "SELECT stage, max(total) AS peak, {} FROM trace GROUP BY stage ORDER BY stage",
        comps.join(", ")
    );
    for spec in registry() {
        // DualPipe on the mini preset (p=2) needs an even m >= 4.
        let m = if spec == ScheduleSpec::DualPipe { 4 } else { rng.range(2, 8) };
        let zero = ["none", "os", "os_g", "os_g_params"][rng.below(4) as usize];
        let res = traced_run("mini", spec, m, zero, 2);
        let store = res.trace.as_ref().expect("record_trace populates the store");
        let r = run_query(store, &sql).unwrap();
        assert_eq!(r.rows.len(), res.stages.len(), "{} stage count", spec.name());
        for (row, st) in r.rows.iter().zip(&res.stages) {
            assert_eq!(row[0], Value::Int(st.stage as i64), "{}", spec.name());
            assert_eq!(
                row[1],
                Value::Int(st.timeline.total_peak() as i64),
                "{} stage {} total peak",
                spec.name(),
                st.stage
            );
            for (i, c) in Component::ALL.iter().enumerate() {
                assert_eq!(
                    row[2 + i],
                    Value::Int(st.timeline.peak(*c) as i64),
                    "{} stage {} component {}",
                    spec.name(),
                    st.stage,
                    c.name()
                );
            }
        }
    }
}

/// Steps past warm-up replay the identical op stream, so anchoring the
/// LAG partition at `step > 0` must find zero cross-step drift — for
/// every registered schedule.
#[test]
fn lag_window_confirms_zero_steady_state_drift() {
    for spec in registry() {
        let res = traced_run("mini", spec, 4, "os_g", 3);
        let store = res.trace.as_ref().expect("store populated");
        let r = run_query(
            store,
            "SELECT stage, step, seq, total - lag(total) OVER (PARTITION BY stage, seq \
             ORDER BY step) AS delta FROM trace WHERE step > 0 HAVING abs(delta) > 0",
        )
        .unwrap();
        assert!(
            r.rows.is_empty(),
            "{}: cross-step drift in steady state: {:?}",
            spec.name(),
            r.rows.first()
        );
    }
}

/// The growth detector over a full 3-step trace flags only step-1 rows:
/// step 0's ordinals include the setup allocations, so step 1 surfaces as
/// warm-up divergence, while step-2 rows (steady state) never appear.
#[test]
fn growth_detector_flags_only_warmup_divergence() {
    let res = traced_run("mini", ScheduleSpec::OneFOneB, 4, "os_g", 3);
    let store = res.trace.as_ref().expect("store populated");
    let r = run_query(store, &growth_sql(1, 100_000)).unwrap();
    assert!(!r.rows.is_empty(), "a 1-byte threshold must catch the warm-up misalignment");
    let step_ix = r.columns.iter().position(|c| c == "step").unwrap();
    for row in &r.rows {
        assert_eq!(row[step_ix], Value::Int(1), "steady-state row flagged as growth: {row:?}");
    }
}

/// Acceptance gate: `dsmem query` over a DualPipe PP16 sim returns
/// byte-identical results via the CLI (`--json`), the scenario runner and
/// `POST /query` — all three surfaces resolve to one spec and one
/// execution path.
#[test]
fn query_is_byte_identical_across_cli_runner_and_server() {
    let sql = "SELECT stage, max(total) AS peak_total, count(*) AS events FROM trace \
               GROUP BY stage ORDER BY peak_total DESC, stage";
    let toml = format!(
        "model = \"v3\"\naction = \"query\"\n\n[activation]\nmicro_batch = 1\n\
         recompute = \"none\"\n\n[query]\nschedule = \"dualpipe\"\nmicrobatches = 32\n\
         zero = \"os_g\"\nsteps = 2\nsql = \"{sql}\"\n"
    );
    let spec = ScenarioSpec::from_toml(&toml, "cli-query").expect("query scenario parses");
    let direct = format!("{}\n", scenario::run_scenario(&spec).expect("direct run").pretty());

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_dsmem"))
        .args([
            "query",
            sql,
            "--model",
            "v3",
            "--schedule",
            "dualpipe",
            "--microbatches",
            "32",
            "--json",
        ])
        .output()
        .expect("CLI runs");
    assert!(out.status.success(), "CLI failed: {}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(
        String::from_utf8(out.stdout).expect("CLI output is UTF-8"),
        direct,
        "CLI --json diverges from the runner snapshot"
    );

    let handle =
        start(&ServerConfig { addr: "127.0.0.1:0".into(), threads: 2 }).expect("server boots");
    let mut client = ServerClient::connect(&handle.addr().to_string()).expect("client connects");
    let served = client.post_scenario("query", "cli-query", &toml).expect("served query answers");
    assert_eq!(served, direct, "POST /query diverges from the runner snapshot");
    drop(client);
    handle.shutdown();
}
