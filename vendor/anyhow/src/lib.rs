//! Offline drop-in for the `anyhow` error crate — only the surface this
//! workspace actually uses: [`Error`], [`Result`], `anyhow!`, `bail!` and
//! `ensure!`. The container builds with no registry access, so the real
//! crate cannot be fetched; this implementation is intentionally tiny
//! (no backtraces, no context chains) but keeps the same types and macro
//! semantics so the workspace compiles unchanged against either.

use std::error::Error as StdError;
use std::fmt;

/// A boxed dynamic error, display-formatted like `anyhow::Error`.
///
/// Deliberately does *not* implement `std::error::Error` itself — exactly
/// like the real crate — so the blanket `From<E: Error>` below does not
/// collide with the reflexive `From<T> for T`.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Build an error from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Build from any standard error (what `?` conversions go through).
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Self { inner: Box::new(error) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `unwrap()` on a Result<_, Error> prints through here; show the
        // message rather than the struct shape, as anyhow does.
        fmt::Display::fmt(&self.inner, f)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Message-only payload behind [`Error::msg`].
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// `anyhow::Result<T>` — a `Result` defaulting its error to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macro_and_question_mark_interop() {
        fn parse(s: &str) -> crate::Result<u64> {
            let n: u64 = s.parse()?; // std error converts via the blanket From
            crate::ensure!(n > 0, "want positive, got {n}");
            if n > 100 {
                crate::bail!("too big: {n}");
            }
            Ok(n)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        assert!(parse("0").unwrap_err().to_string().contains("positive"));
        assert!(parse("101").unwrap_err().to_string().contains("too big"));
        let e = crate::anyhow!("ctx {}", 42);
        assert_eq!(format!("{e}"), "ctx 42");
        assert_eq!(format!("{e:?}"), "ctx 42");
    }
}
