//! Offline compile-stub of the `xla` PJRT bindings used by the `live`
//! feature (`runtime/`, `coordinator/`, `trainer/`).
//!
//! Purpose: keep the live pillar *compiling* (and CI compile-checked) on
//! machines without the real bindings. The host-side [`Literal`] container
//! is fully functional — `vec1`/`scalar`/`reshape`/`to_vec`/`size_bytes`
//! work for real, so the pure-host unit tests of the live modules pass.
//! Everything that would touch PJRT ([`PjRtClient::cpu`],
//! [`PjRtClient::compile`], [`PjRtLoadedExecutable::execute`]) returns an
//! [`Error`] explaining that the stub is in use.
//!
//! To run the live training loop, replace the `vendor/xla-stub` path
//! dependency in the workspace `Cargo.toml` with the real `xla` crate — the
//! API surface here mirrors the subset the live pillar consumes.

use std::fmt;

const STUB: &str =
    "xla-stub: the offline compile-stub is linked; swap in the real xla PJRT bindings to run";

/// Stub error type (the real crate's error is also only `Debug`-formatted by
/// the live pillar).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Internal element storage (public only because the sealed [`NativeType`]
/// trait mentions it; not part of the mirrored API surface).
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types the stub literal can hold (`f32`, `i32` — the two the live
/// pillar stages).
pub trait NativeType: Copy + Sized + sealed::Sealed {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

/// Host-side tensor literal. Fully functional in the stub (it is a plain
/// data container); 4-byte element types only.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// A rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::wrap(vec![v]), dims: vec![] }
    }

    /// A rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel < 0 || numel as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Number of elements.
    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Total bytes (all supported element types are 4 B).
    pub fn size_bytes(&self) -> usize {
        4 * self.data.len()
    }

    /// Copy the elements out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    /// Destructure a tuple literal. The stub never constructs tuples (they
    /// only come back from PJRT execution, which the stub refuses).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error(STUB.into()))
    }
}

/// Stub of a device buffer returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Would synchronously copy the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(STUB.into()))
    }
}

/// Stub of a compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Would execute on the device; the stub always errors.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB.into()))
    }
}

/// Stub of the PJRT client.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Would create a CPU client; the stub always errors (so `dsmem train`
    /// fails fast with a clear message instead of silently no-opping).
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(STUB.into()))
    }

    /// Would compile a computation.
    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB.into()))
    }

    /// Platform name for logging.
    pub fn platform_name(&self) -> String {
        "xla-stub".into()
    }
}

/// Stub of a parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Reads the file (so missing artifacts error early) but does not parse.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read(path)
            .map(|_| HloModuleProto { _private: () })
            .map_err(|e| Error(format!("{path}: {e}")))
    }
}

/// Stub of an XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wraps a proto.
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_container_is_functional() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.size_bytes(), 16);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(Literal::vec1(&[1i32, 2]).reshape(&[3]).is_err());
        assert_eq!(Literal::scalar(7.5f32).element_count(), 1);
    }

    #[test]
    fn pjrt_paths_error_with_stub_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla-stub"));
    }
}
